//! The length-prefixed binary wire protocol of the TCP front-end.
//!
//! Every frame is an 8-byte header — magic `0xD1A7` (u16 LE), protocol
//! version (u8), frame kind (u8), payload length (u32 LE) — followed by
//! `len` payload bytes. Integers are little-endian throughout; strings
//! are u16-length-prefixed UTF-8; tensors are a u8 rank, u32 dimensions
//! and raw f32 LE data whose element count must equal the dimension
//! product. Anything violating the framing — bad magic, unsupported
//! version, unknown kind, payload over [`MAX_PAYLOAD`], short reads,
//! trailing bytes — decodes to the typed
//! [`DynamapError::Protocol`], never a panic, so a malicious or
//! confused peer cannot take down a server thread.
//!
//! [`read_frame`] distinguishes three outcomes a server loop needs:
//! `Ok(Some(frame))` (a complete frame), `Ok(None)` (clean EOF on a
//! frame boundary — the peer hung up) and `Err(..)` (protocol violation
//! or transport failure).

use std::io::{Read, Write};

use crate::api::DynamapError;
use crate::runtime::TensorBuf;

/// Frame magic: the first two header bytes of every DYNAMAP frame.
pub const MAGIC: u16 = 0xD1A7;
/// Current protocol version; bumped on any incompatible framing change.
/// Version 2 adds an optional trailing deadline to [`Frame::Infer`] and
/// the [`WireError::DeadlineExceeded`] reply. Version 3 widens the
/// [`Frame::Infer`] trailer to optionally carry a trace id (see the
/// trailer grammar on [`Frame::Infer::trace`]) and adds the
/// [`Frame::Stats`] / [`Frame::TraceDump`] observability requests.
pub const VERSION: u8 = 3;
/// Oldest protocol version still accepted on the read side. Decoding is
/// presence-based, not version-gated: a version-1 `Infer` body is
/// exactly a version-3 body with the trailer absent, and a version-2
/// body is one with the 8-byte deadline-only trailer, so old peers keep
/// working against a v3 server (and vice versa for requests that don't
/// carry the newer fields).
pub const MIN_VERSION: u8 = 1;
/// Hard cap on a frame payload (64 MiB) — read before allocating, so an
/// adversarial length field cannot force a huge allocation.
pub const MAX_PAYLOAD: u32 = 64 << 20;
/// Hard cap on tensor rank over the wire.
pub const MAX_RANK: u8 = 8;

/// One protocol message, request or response.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Request: serve one inference for `model`.
    Infer {
        /// Zoo model name (aliases accepted, as in [`crate::serve::ModelRegistry`]).
        model: String,
        /// Input tensor.
        input: TensorBuf,
        /// Optional request deadline, milliseconds from the moment the
        /// server decodes the frame. `None` (and every version-1 frame)
        /// means "no deadline". When set, the server sheds the request
        /// with [`WireError::DeadlineExceeded`] instead of computing a
        /// result nobody is waiting for.
        deadline_ms: Option<u64>,
        /// Optional request trace id (version-3 extension): when set,
        /// every span the request produces server-side is stamped with
        /// this id, so a `TraceDump` correlates wire requests to
        /// admission/queue/flush/layer spans.
        ///
        /// Trailer grammar (everything after the input tensor):
        /// 0 bytes ⇒ no deadline, no trace (the v1 body); 8 bytes ⇒
        /// deadline only (the v2 body); 16 bytes ⇒ deadline then trace,
        /// with a `u64::MAX` deadline meaning "no deadline" so the two
        /// optional fields stay independently expressible. (A real
        /// deadline of `u64::MAX` ms — 584 million years — is therefore
        /// not representable alongside a trace; it decodes as `None`.)
        /// Any other trailer length is a typed protocol error.
        trace: Option<crate::obs::TraceId>,
    },
    /// Request: liveness probe.
    Ping,
    /// Request: begin graceful drain and shut the server down.
    Shutdown,
    /// Response to [`Frame::Infer`]: the output plus server-side
    /// end-to-end latency in microseconds.
    InferOk {
        /// Output tensor (bitwise-equal to `Session::infer`).
        output: TensorBuf,
        /// Server-side end-to-end latency, µs.
        server_us: f64,
    },
    /// Response to [`Frame::Ping`].
    Pong,
    /// Response to [`Frame::Shutdown`]: drain has begun.
    ShutdownAck,
    /// Request (version 3): snapshot the server's metrics — per-model
    /// counters plus the full latency histograms — as one JSON
    /// document, so `dynamap stats --connect` and the benches scrape a
    /// live server instead of parsing the REPL table.
    Stats,
    /// Response to [`Frame::Stats`].
    StatsOk {
        /// JSON document (`ServerMetrics` snapshot incl. per-model
        /// [`crate::obs::LogHistogram`] buckets).
        json: String,
    },
    /// Request (version 3): drain the server's span recorder and return
    /// the spans as Chrome trace-event JSON. Collect-then-fetch: each
    /// dump returns the spans recorded since the previous dump.
    TraceDump,
    /// Response to [`Frame::TraceDump`]; `{"traceEvents": []}` when no
    /// recorder is installed server-side.
    TraceDumpOk {
        /// Chrome trace-event JSON document
        /// ([`crate::obs::chrome_trace`] output), Perfetto-loadable.
        json: String,
    },
    /// Typed failure response to any request.
    Error(WireError),
}

/// The error taxonomy a server can put on the wire — the serving-path
/// subset of [`DynamapError`], flattened into stable wire codes.
#[derive(Debug, Clone, PartialEq)]
pub enum WireError {
    /// Admission control shed the request; retriable after the hint.
    Overloaded {
        /// Model whose in-flight budget was full.
        model: String,
        /// Suggested backoff, milliseconds.
        retry_after_ms: u64,
    },
    /// The model is not in the zoo registry.
    UnknownModel(String),
    /// Input tensor shape mismatch.
    Shape {
        /// What was being validated.
        context: String,
        /// Expected element count.
        expected: u64,
        /// Received element count.
        got: u64,
    },
    /// The request's deadline expired before compute ran; the request
    /// was shed without occupying a batch slot. Not retriable as-is —
    /// the client must mint a fresh deadline.
    DeadlineExceeded {
        /// Model the expired request was addressed to.
        model: String,
        /// How long the request waited before being shed, milliseconds.
        waited_ms: u64,
    },
    /// The model's queue is shut down (eviction race or drain); retriable.
    QueueClosed {
        /// Model whose queue was gone.
        model: String,
    },
    /// The peer violated the framing; the connection will close.
    Protocol(String),
    /// Any other server-side failure, stringified.
    Server(String),
}

impl From<DynamapError> for WireError {
    fn from(e: DynamapError) -> WireError {
        match e {
            DynamapError::Overloaded { model, retry_after_ms } => {
                WireError::Overloaded { model, retry_after_ms }
            }
            DynamapError::UnknownModel(m) => WireError::UnknownModel(m),
            DynamapError::Shape { context, expected, got } => WireError::Shape {
                context,
                expected: expected as u64,
                got: got as u64,
            },
            DynamapError::DeadlineExceeded { model, waited_ms } => {
                WireError::DeadlineExceeded { model, waited_ms }
            }
            DynamapError::QueueClosed { model } => WireError::QueueClosed { model },
            DynamapError::Protocol(m) => WireError::Protocol(m),
            other => WireError::Server(other.to_string()),
        }
    }
}

impl From<WireError> for DynamapError {
    fn from(e: WireError) -> DynamapError {
        match e {
            WireError::Overloaded { model, retry_after_ms } => {
                DynamapError::Overloaded { model, retry_after_ms }
            }
            WireError::UnknownModel(m) => DynamapError::UnknownModel(m),
            WireError::Shape { context, expected, got } => DynamapError::Shape {
                context,
                expected: expected as usize,
                got: got as usize,
            },
            WireError::DeadlineExceeded { model, waited_ms } => {
                DynamapError::DeadlineExceeded { model, waited_ms }
            }
            WireError::QueueClosed { model } => DynamapError::QueueClosed { model },
            WireError::Protocol(m) => DynamapError::Protocol(m),
            WireError::Server(m) => DynamapError::Serve(m),
        }
    }
}

// frame kinds (header byte 3)
const K_INFER: u8 = 1;
const K_PING: u8 = 2;
const K_SHUTDOWN: u8 = 3;
const K_INFER_OK: u8 = 4;
const K_PONG: u8 = 5;
const K_SHUTDOWN_ACK: u8 = 6;
const K_ERROR: u8 = 7;
const K_STATS: u8 = 8;
const K_STATS_OK: u8 = 9;
const K_TRACE: u8 = 10;
const K_TRACE_OK: u8 = 11;

// wire-error codes (first payload byte of an Error frame)
const E_OVERLOADED: u8 = 1;
const E_UNKNOWN_MODEL: u8 = 2;
const E_SHAPE: u8 = 3;
const E_QUEUE_CLOSED: u8 = 4;
const E_PROTOCOL: u8 = 5;
const E_SERVER: u8 = 6;
const E_DEADLINE: u8 = 7;

fn proto(msg: impl Into<String>) -> DynamapError {
    DynamapError::Protocol(msg.into())
}

/// Longest prefix of `s` that fits `max` bytes without splitting a
/// UTF-8 code point (strings are u16-length-prefixed on the wire).
fn clip_utf8(s: &str, max: usize) -> &str {
    if s.len() <= max {
        return s;
    }
    let mut end = max;
    while end > 0 && !s.is_char_boundary(end) {
        end -= 1;
    }
    &s[..end]
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    let s = clip_utf8(s, u16::MAX as usize);
    buf.extend_from_slice(&(s.len() as u16).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// u32-length-prefixed UTF-8 string, for document bodies that can
/// exceed [`put_str`]'s u16 cap (the JSON of `StatsOk`/`TraceDumpOk`).
/// Clipped at the payload cap; the overall frame length check still
/// bounds what a peer can make us allocate.
fn put_lstr(buf: &mut Vec<u8>, s: &str) {
    let s = clip_utf8(s, MAX_PAYLOAD as usize - 4);
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

fn put_tensor(buf: &mut Vec<u8>, t: &TensorBuf) {
    buf.push(t.shape.len() as u8);
    for &d in &t.shape {
        buf.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for v in &t.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
}

/// Bounds-checked cursor over one frame payload.
struct Cur<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], DynamapError> {
        if self.buf.len() - self.pos < n {
            return Err(proto(format!(
                "payload too short: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.buf.len() - self.pos
            )));
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, DynamapError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, DynamapError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, DynamapError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, DynamapError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, DynamapError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> Result<String, DynamapError> {
        let len = self.u16()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| proto("string field is not valid UTF-8"))
    }

    /// u32-length-prefixed counterpart of [`Cur::str`] (see
    /// [`put_lstr`]). The length is bounds-checked against the payload
    /// by `take`, so a lying prefix is a typed error, not an
    /// allocation.
    fn lstr(&mut self) -> Result<String, DynamapError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| proto("string field is not valid UTF-8"))
    }

    fn tensor(&mut self) -> Result<TensorBuf, DynamapError> {
        let rank = self.u8()?;
        if rank == 0 || rank > MAX_RANK {
            return Err(proto(format!("tensor rank {rank} outside 1..={MAX_RANK}")));
        }
        let mut shape = Vec::with_capacity(rank as usize);
        let mut count: u64 = 1;
        for _ in 0..rank {
            let d = self.u32()? as u64;
            shape.push(d as usize);
            // overflow-proof: reject the moment the running product can
            // no longer fit the payload cap
            count = count
                .checked_mul(d)
                .filter(|&c| c <= u64::from(MAX_PAYLOAD) / 4)
                .ok_or_else(|| {
                    proto(format!("tensor shape {shape:?}… exceeds the payload cap"))
                })?;
        }
        let bytes = self.take(count as usize * 4)?;
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(TensorBuf::new(shape, data))
    }

    fn finish(self) -> Result<(), DynamapError> {
        if self.pos != self.buf.len() {
            return Err(proto(format!(
                "{} trailing bytes after a complete frame body",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Serialize `frame` (header + payload) into a fresh byte vector.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let (kind, payload) = match frame {
        Frame::Infer { model, input, deadline_ms, trace } => {
            let mut p = Vec::with_capacity(input.data.len() * 4 + 64);
            put_str(&mut p, model);
            put_tensor(&mut p, input);
            // optional trailer (see the grammar on `Frame::Infer::trace`):
            // nothing ⇒ v1 body; deadline only ⇒ v2 body; a trace id
            // always rides behind a deadline word (u64::MAX = "none")
            // so the 8- and 16-byte trailers stay distinguishable
            match (deadline_ms, trace) {
                (None, None) => {}
                (Some(ms), None) => p.extend_from_slice(&ms.to_le_bytes()),
                (dl, Some(t)) => {
                    p.extend_from_slice(&dl.unwrap_or(u64::MAX).to_le_bytes());
                    p.extend_from_slice(&t.raw().to_le_bytes());
                }
            }
            (K_INFER, p)
        }
        Frame::Ping => (K_PING, Vec::new()),
        Frame::Shutdown => (K_SHUTDOWN, Vec::new()),
        Frame::InferOk { output, server_us } => {
            let mut p = Vec::with_capacity(output.data.len() * 4 + 64);
            p.extend_from_slice(&server_us.to_le_bytes());
            put_tensor(&mut p, output);
            (K_INFER_OK, p)
        }
        Frame::Pong => (K_PONG, Vec::new()),
        Frame::ShutdownAck => (K_SHUTDOWN_ACK, Vec::new()),
        Frame::Stats => (K_STATS, Vec::new()),
        Frame::StatsOk { json } => {
            let mut p = Vec::with_capacity(json.len() + 4);
            put_lstr(&mut p, json);
            (K_STATS_OK, p)
        }
        Frame::TraceDump => (K_TRACE, Vec::new()),
        Frame::TraceDumpOk { json } => {
            let mut p = Vec::with_capacity(json.len() + 4);
            put_lstr(&mut p, json);
            (K_TRACE_OK, p)
        }
        Frame::Error(e) => {
            let mut p = Vec::new();
            match e {
                WireError::Overloaded { model, retry_after_ms } => {
                    p.push(E_OVERLOADED);
                    put_str(&mut p, model);
                    p.extend_from_slice(&retry_after_ms.to_le_bytes());
                }
                WireError::UnknownModel(m) => {
                    p.push(E_UNKNOWN_MODEL);
                    put_str(&mut p, m);
                }
                WireError::Shape { context, expected, got } => {
                    p.push(E_SHAPE);
                    put_str(&mut p, context);
                    p.extend_from_slice(&expected.to_le_bytes());
                    p.extend_from_slice(&got.to_le_bytes());
                }
                WireError::QueueClosed { model } => {
                    p.push(E_QUEUE_CLOSED);
                    put_str(&mut p, model);
                }
                WireError::Protocol(m) => {
                    p.push(E_PROTOCOL);
                    put_str(&mut p, m);
                }
                WireError::Server(m) => {
                    p.push(E_SERVER);
                    put_str(&mut p, m);
                }
                WireError::DeadlineExceeded { model, waited_ms } => {
                    p.push(E_DEADLINE);
                    put_str(&mut p, model);
                    p.extend_from_slice(&waited_ms.to_le_bytes());
                }
            }
            (K_ERROR, p)
        }
    };
    debug_assert!(payload.len() as u64 <= u64::from(MAX_PAYLOAD));
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(kind);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode one frame body given its header `kind` and `payload`.
fn decode_payload(kind: u8, payload: &[u8]) -> Result<Frame, DynamapError> {
    let mut cur = Cur { buf: payload, pos: 0 };
    let frame = match kind {
        K_INFER => {
            let model = cur.str()?;
            let input = cur.tensor()?;
            // versioned trailer, decoded by presence (see the grammar
            // on `Frame::Infer::trace`): 0 bytes = v1, 8 = v2 deadline,
            // 16 = v3 deadline (u64::MAX sentinel = none) + trace id
            let (deadline_ms, trace) = match cur.buf.len() - cur.pos {
                0 => (None, None),
                8 => (Some(cur.u64()?), None),
                16 => {
                    let dl = cur.u64()?;
                    let t = crate::obs::TraceId::from_raw(cur.u64()?);
                    ((dl != u64::MAX).then_some(dl), Some(t))
                }
                n => {
                    return Err(proto(format!(
                        "Infer trailer of {n} bytes (want 0, 8 or 16)"
                    )))
                }
            };
            Frame::Infer { model, input, deadline_ms, trace }
        }
        K_PING => Frame::Ping,
        K_SHUTDOWN => Frame::Shutdown,
        K_INFER_OK => {
            let server_us = cur.f64()?;
            let output = cur.tensor()?;
            Frame::InferOk { output, server_us }
        }
        K_PONG => Frame::Pong,
        K_SHUTDOWN_ACK => Frame::ShutdownAck,
        K_STATS => Frame::Stats,
        K_STATS_OK => Frame::StatsOk { json: cur.lstr()? },
        K_TRACE => Frame::TraceDump,
        K_TRACE_OK => Frame::TraceDumpOk { json: cur.lstr()? },
        K_ERROR => {
            let code = cur.u8()?;
            let err = match code {
                E_OVERLOADED => {
                    let model = cur.str()?;
                    let retry_after_ms = cur.u64()?;
                    WireError::Overloaded { model, retry_after_ms }
                }
                E_UNKNOWN_MODEL => WireError::UnknownModel(cur.str()?),
                E_SHAPE => {
                    let context = cur.str()?;
                    let expected = cur.u64()?;
                    let got = cur.u64()?;
                    WireError::Shape { context, expected, got }
                }
                E_QUEUE_CLOSED => WireError::QueueClosed { model: cur.str()? },
                E_PROTOCOL => WireError::Protocol(cur.str()?),
                E_SERVER => WireError::Server(cur.str()?),
                E_DEADLINE => {
                    let model = cur.str()?;
                    let waited_ms = cur.u64()?;
                    WireError::DeadlineExceeded { model, waited_ms }
                }
                other => return Err(proto(format!("unknown wire-error code {other}"))),
            };
            Frame::Error(err)
        }
        other => return Err(proto(format!("unknown frame kind {other}"))),
    };
    cur.finish()?;
    Ok(frame)
}

/// Read one frame from `r`.
///
/// Returns `Ok(None)` on a clean EOF at a frame boundary (the peer
/// closed the connection), [`DynamapError::Protocol`] on any framing
/// violation (including EOF mid-frame) and [`DynamapError::Net`] on
/// transport failure.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>, DynamapError> {
    // header, byte-at-a-time loop so "no frame at all" (clean close) is
    // distinguishable from "half a header" (truncation)
    let mut header = [0u8; 8];
    let mut got = 0usize;
    while got < header.len() {
        match r.read(&mut header[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None);
                }
                return Err(proto(format!("truncated header: {got}/8 bytes")));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(DynamapError::Net(format!("read failed: {e}"))),
        }
    }
    let magic = u16::from_le_bytes([header[0], header[1]]);
    if magic != MAGIC {
        return Err(proto(format!("bad magic {magic:#06x} (want {MAGIC:#06x})")));
    }
    if header[2] < MIN_VERSION || header[2] > VERSION {
        return Err(proto(format!(
            "unsupported protocol version {} (speak {MIN_VERSION}..={VERSION})",
            header[2]
        )));
    }
    let kind = header[3];
    let len = u32::from_le_bytes([header[4], header[5], header[6], header[7]]);
    if len > MAX_PAYLOAD {
        return Err(proto(format!("oversized frame: {len} bytes > cap {MAX_PAYLOAD}")));
    }
    let mut payload = vec![0u8; len as usize];
    if let Err(e) = r.read_exact(&mut payload) {
        return Err(match e.kind() {
            std::io::ErrorKind::UnexpectedEof => {
                proto(format!("truncated payload: wanted {len} bytes"))
            }
            _ => DynamapError::Net(format!("read failed: {e}")),
        });
    }
    decode_payload(kind, &payload).map(Some)
}

/// Serialize `frame` and write it to `w` (single `write_all` + flush).
/// Transport failures map to [`DynamapError::Net`].
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), DynamapError> {
    let bytes = encode_frame(frame);
    w.write_all(&bytes)
        .and_then(|_| w.flush())
        .map_err(|e| DynamapError::Net(format!("write failed: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_string(rng: &mut Rng) -> String {
        let pool = [
            "mini", "mini-inception", "Ω-model", "a", "", "模型", "zoo/π",
        ];
        let mut s = (*rng.choose(&pool)).to_string();
        for _ in 0..rng.below(8) {
            s.push((b'a' + rng.below(26) as u8) as char);
        }
        s
    }

    fn rand_tensor(rng: &mut Rng) -> TensorBuf {
        let rank = rng.range(1, 4);
        let mut shape = Vec::new();
        let mut count = 1usize;
        for _ in 0..rank {
            let d = rng.range(1, 8);
            shape.push(d);
            count *= d;
        }
        let data = (0..count).map(|_| rng.f32_range(-1e3, 1e3)).collect();
        TensorBuf::new(shape, data)
    }

    fn rand_frame(rng: &mut Rng) -> Frame {
        match rng.below(12) {
            0 => Frame::Ping,
            1 => Frame::Pong,
            2 => Frame::Shutdown,
            3 => Frame::ShutdownAck,
            4 => Frame::Infer {
                model: rand_string(rng),
                input: rand_tensor(rng),
                deadline_ms: if rng.bool() { Some(rng.below(100_000)) } else { None },
                trace: if rng.bool() {
                    Some(crate::obs::TraceId::derive(99, rng.below(1 << 30)))
                } else {
                    None
                },
            },
            5 => Frame::InferOk {
                output: rand_tensor(rng),
                server_us: rng.f64() * 1e6,
            },
            6 => Frame::Error(WireError::Overloaded {
                model: rand_string(rng),
                retry_after_ms: rng.below(10_000),
            }),
            7 => Frame::Error(WireError::UnknownModel(rand_string(rng))),
            8 => Frame::Error(WireError::Shape {
                context: rand_string(rng),
                expected: rng.below(1 << 20),
                got: rng.below(1 << 20),
            }),
            9 => {
                if rng.bool() {
                    Frame::Stats
                } else {
                    Frame::TraceDump
                }
            }
            10 => {
                // document bodies round trip through the u32-prefixed
                // string, including ones past put_str's u16 cap
                let json = if rng.below(8) == 0 {
                    format!("{{\"pad\": \"{}\"}}", "x".repeat(70_000))
                } else {
                    format!("{{\"n\": {}}}", rng.below(1 << 20))
                };
                if rng.bool() {
                    Frame::StatsOk { json }
                } else {
                    Frame::TraceDumpOk { json }
                }
            }
            _ => {
                let opts = [
                    WireError::QueueClosed { model: rand_string(rng) },
                    WireError::Protocol(rand_string(rng)),
                    WireError::Server(rand_string(rng)),
                    WireError::DeadlineExceeded {
                        model: rand_string(rng),
                        waited_ms: rng.below(100_000),
                    },
                ];
                Frame::Error(rng.choose(&opts).clone())
            }
        }
    }

    #[test]
    fn round_trip_random_frames() {
        check("frame round trip", 256, |rng| {
            let frame = rand_frame(rng);
            let bytes = encode_frame(&frame);
            let mut cursor = &bytes[..];
            let back = read_frame(&mut cursor)
                .map_err(|e| format!("decode failed: {e}"))?
                .ok_or("decoded EOF from a full frame")?;
            if back != frame {
                return Err(format!("{frame:?} → {back:?}"));
            }
            if !cursor.is_empty() {
                return Err(format!("{} bytes left unread", cursor.len()));
            }
            Ok(())
        });
    }

    #[test]
    fn clean_eof_and_back_to_back_frames() {
        let mut empty: &[u8] = &[];
        assert_eq!(read_frame(&mut empty).unwrap(), None);

        // two frames on one stream decode in order
        let mut bytes = encode_frame(&Frame::Ping);
        bytes.extend(encode_frame(&Frame::Pong));
        let mut cursor = &bytes[..];
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(Frame::Ping));
        assert_eq!(read_frame(&mut cursor).unwrap(), Some(Frame::Pong));
        assert_eq!(read_frame(&mut cursor).unwrap(), None);
    }

    #[test]
    fn truncated_frames_are_typed_protocol_errors() {
        check("truncation", 128, |rng| {
            let frame = rand_frame(rng);
            let bytes = encode_frame(&frame);
            // cut anywhere strictly inside the frame (1..len)
            let cut = 1 + rng.below(bytes.len() as u64 - 1) as usize;
            let mut cursor = &bytes[..cut];
            match read_frame(&mut cursor) {
                Err(DynamapError::Protocol(_)) => Ok(()),
                other => Err(format!("cut at {cut}/{}: {other:?}", bytes.len())),
            }
        });
    }

    #[test]
    fn corrupt_headers_are_typed_protocol_errors() {
        let good = encode_frame(&Frame::Ping);

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xFF;
        let e = read_frame(&mut &bad_magic[..]).unwrap_err();
        assert!(matches!(e, DynamapError::Protocol(_)), "{e}");
        assert!(e.to_string().contains("magic"), "{e}");

        let mut bad_version = good.clone();
        bad_version[2] = VERSION + 1;
        let e = read_frame(&mut &bad_version[..]).unwrap_err();
        assert!(e.to_string().contains("version"), "{e}");

        let mut bad_kind = good.clone();
        bad_kind[3] = 200;
        let e = read_frame(&mut &bad_kind[..]).unwrap_err();
        assert!(e.to_string().contains("kind"), "{e}");

        // oversized length field is rejected *before* allocation — no
        // 4 GiB buffer, no waiting for bytes that will never come
        let mut oversized = good.clone();
        oversized[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let e = read_frame(&mut &oversized[..]).unwrap_err();
        assert!(e.to_string().contains("oversized"), "{e}");
    }

    #[test]
    fn malformed_bodies_are_typed_protocol_errors() {
        // trailing junk after a complete body
        let mut bytes = encode_frame(&Frame::Ping);
        bytes[4..8].copy_from_slice(&4u32.to_le_bytes());
        bytes.extend_from_slice(&[0, 0, 0, 0]);
        let e = read_frame(&mut &bytes[..]).unwrap_err();
        assert!(e.to_string().contains("trailing"), "{e}");

        // tensor whose declared shape exceeds the payload cap
        let mut body = Vec::new();
        put_str(&mut body, "mini");
        body.push(2); // rank 2
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        let e = decode_payload(K_INFER, &body).unwrap_err();
        assert!(e.to_string().contains("payload cap"), "{e}");

        // zero-rank tensor
        let mut body = Vec::new();
        put_str(&mut body, "mini");
        body.push(0);
        let e = decode_payload(K_INFER, &body).unwrap_err();
        assert!(matches!(e, DynamapError::Protocol(_)), "{e}");

        // invalid UTF-8 in a string field (an Infer body starts with
        // the model name: u16 len = 3, then three non-UTF-8 bytes)
        let body = [3u8, 0, 0xFF, 0xFE, 0xFD];
        let e = decode_payload(K_INFER, &body).unwrap_err();
        assert!(matches!(e, DynamapError::Protocol(_)), "{e}");
    }

    #[test]
    fn version1_infer_frames_decode_as_no_deadline() {
        // a v1 Infer body is exactly a v2 body without the trailing
        // deadline; stamping the old version byte must still decode
        let frame = Frame::Infer {
            model: "mini".into(),
            input: TensorBuf::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            deadline_ms: None,
            trace: None,
        };
        let mut bytes = encode_frame(&frame);
        assert_eq!(bytes[2], VERSION);
        bytes[2] = MIN_VERSION;
        let back = read_frame(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(back, frame, "v1 framing reads back as deadline-free");

        // and a deadline survives a v2 round trip
        let frame = Frame::Infer {
            model: "mini".into(),
            input: TensorBuf::new(vec![1], vec![0.5]),
            deadline_ms: Some(250),
            trace: None,
        };
        let bytes = encode_frame(&frame);
        let back = read_frame(&mut &bytes[..]).unwrap().unwrap();
        assert_eq!(back, frame);
    }

    #[test]
    fn v3_trailer_grammar() {
        let infer = |deadline_ms: Option<u64>, trace: Option<crate::obs::TraceId>| Frame::Infer {
            model: "mini".into(),
            input: TensorBuf::new(vec![2], vec![1.0, 2.0]),
            deadline_ms,
            trace,
        };
        let base_len = encode_frame(&infer(None, None)).len();

        // deadline-only bodies stay byte-identical to v2 (8-byte trailer)
        assert_eq!(encode_frame(&infer(Some(250), None)).len(), base_len + 8);

        // trace without deadline: 16-byte trailer with the MAX sentinel
        let trace = crate::obs::TraceId::derive(99, 7);
        let traced = infer(None, Some(trace));
        let bytes = encode_frame(&traced);
        assert_eq!(bytes.len(), base_len + 16);
        assert_eq!(
            &bytes[bytes.len() - 16..bytes.len() - 8],
            &u64::MAX.to_le_bytes(),
            "absent deadline rides as the u64::MAX sentinel"
        );
        assert_eq!(read_frame(&mut &bytes[..]).unwrap().unwrap(), traced);

        // both: the deadline word carries the real value
        let both = infer(Some(250), Some(trace));
        let bytes = encode_frame(&both);
        assert_eq!(bytes.len(), base_len + 16);
        assert_eq!(read_frame(&mut &bytes[..]).unwrap().unwrap(), both);

        // a malformed trailer length must be a typed protocol error
        let mut bytes = encode_frame(&infer(None, None));
        let new_len = (bytes.len() - 8 + 4) as u32;
        bytes[4..8].copy_from_slice(&new_len.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 4]);
        let e = read_frame(&mut &bytes[..]).unwrap_err();
        assert!(e.to_string().contains("trailer"), "{e}");
    }

    #[test]
    fn wire_errors_round_trip_through_dynamap_errors() {
        let cases = vec![
            DynamapError::Overloaded { model: "mini".into(), retry_after_ms: 3 },
            DynamapError::UnknownModel("ghost".into()),
            DynamapError::Shape { context: "input".into(), expected: 1024, got: 7 },
            DynamapError::QueueClosed { model: "mini".into() },
            DynamapError::DeadlineExceeded { model: "mini".into(), waited_ms: 42 },
            DynamapError::Protocol("bad magic".into()),
        ];
        for e in cases {
            let msg = e.to_string();
            let wire: WireError = e.into();
            let back: DynamapError = wire.into();
            assert_eq!(back.to_string(), msg, "lossless for serving-path variants");
        }
        // everything else flattens to a stringly Server error
        let wire: WireError = DynamapError::Dse("no plans".into()).into();
        assert!(matches!(wire, WireError::Server(_)));
    }
}
