//! im2col convolution (§2.1.1): lower to one GEMM
//! `W (C_out × K1K2C_in) × X (K1K2C_in × O1O2)` over the Toeplitz matrix.

use super::tensor::{Mat, Tensor, Weights};
use crate::graph::layer::ConvSpec;

/// Build the Toeplitz (im2col) matrix: each column is one `K1K2·C_in`
/// sliding window, columns ordered by output pixel (row-major o1, o2).
/// Row index is `(ci · K1 + ky) · K2 + kx`.
pub fn toeplitz(input: &Tensor, spec: &ConvSpec) -> Mat {
    let (o1, o2) = (spec.o1(), spec.o2());
    let rows = spec.k1 * spec.k2 * spec.c_in;
    let cols = o1 * o2;
    let mut m = Mat::zeros(rows, cols);
    for ci in 0..spec.c_in {
        for ky in 0..spec.k1 {
            for kx in 0..spec.k2 {
                let r = (ci * spec.k1 + ky) * spec.k2 + kx;
                for oy in 0..o1 {
                    for ox in 0..o2 {
                        let iy = (oy * spec.s + ky) as isize - spec.p1 as isize;
                        let ix = (ox * spec.s + kx) as isize - spec.p2 as isize;
                        m.set(r, oy * o2 + ox, input.get_padded(ci, iy, ix));
                    }
                }
            }
        }
    }
    m
}

/// Flatten weights to the `C_out × K1K2C_in` kernel matrix matching
/// [`toeplitz`] row order.
pub fn weight_matrix(weights: &Weights) -> Mat {
    let cols = weights.k1 * weights.k2 * weights.c_in;
    Mat::from_fn(weights.c_out, cols, |co, j| {
        let ci = j / (weights.k1 * weights.k2);
        let rem = j % (weights.k1 * weights.k2);
        let ky = rem / weights.k2;
        let kx = rem % weights.k2;
        weights.get(co, ci, ky, kx)
    })
}

/// im2col convolution (Eq. 2).
pub fn conv2d(input: &Tensor, weights: &Weights, spec: &ConvSpec) -> Tensor {
    let x = toeplitz(input, spec);
    let w = weight_matrix(weights);
    let z = w.matmul(&x); // (C_out × O1O2)
    let (o1, o2) = (spec.o1(), spec.o2());
    Tensor { c: spec.c_out, h: o1, w: o2, data: z.data }
}

/// Random layer spec generator shared by the algorithm property tests.
#[cfg(test)]
pub(crate) fn random_spec(r: &mut crate::util::rng::Rng) -> ConvSpec {
    let k1 = *r.choose(&[1usize, 3, 5, 7]);
    let k2 = if r.bool() { k1 } else { *r.choose(&[1usize, 3, 5, 7]) };
    let s = r.range(1, 2);
    let h1 = r.range(k1.max(4), 10);
    let h2 = r.range(k2.max(4), 10);
    let c_in = r.range(1, 4);
    let c_out = r.range(1, 4);
    let (p1, p2) = if r.bool() { (k1 / 2, k2 / 2) } else { (0, 0) };
    ConvSpec::new(c_in, c_out, h1, h2, k1, k2, s, p1, p2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::direct;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    #[test]
    fn matches_direct_small() {
        let spec = ConvSpec::new(2, 3, 5, 5, 3, 3, 1, 1, 1);
        let mut rng = Rng::new(1);
        let input = Tensor::random(2, 5, 5, &mut rng);
        let w = Weights::random(3, 2, 3, 3, &mut rng);
        let a = direct::conv2d(&input, &w, &spec);
        let b = conv2d(&input, &w, &spec);
        assert_allclose(&a.data, &b.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn toeplitz_shape() {
        let spec = ConvSpec::new(2, 1, 4, 4, 3, 3, 1, 1, 1);
        let t = toeplitz(&Tensor::zeros(2, 4, 4), &spec);
        assert_eq!((t.rows, t.cols), (18, 16));
    }

    #[test]
    fn property_matches_direct() {
        check("im2col_vs_direct", 48, |r: &mut Rng| {
            let spec = super::random_spec(r);
            let input = Tensor::random_i8(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random_i8(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let a = direct::conv2d(&input, &w, &spec);
            let b = conv2d(&input, &w, &spec);
            // integer-valued data → exact equality
            if a.data != b.data {
                return Err(format!("mismatch for spec {spec:?}"));
            }
            Ok(())
        });
    }

}
