//! Winograd minimal-filtering convolution (§2.1.3, Eq. 5/6).
//!
//! `F(2×2, 3×3)` with the canonical transform matrices
//!
//! ```text
//! Bᵀ = [1  0 -1  0;  0 1 1 0;  0 -1 1 0;  0 1 0 -1]
//! G  = [1 0 0;  ½ ½ ½;  ½ -½ ½;  0 0 1]
//! Aᵀ = [1 1 1 0;  0 1 -1 -1]
//! ```
//!
//! Kernels larger than `r × r` (square, e.g. GoogLeNet's 5×5) are
//! decomposed into `⌈K/r⌉²` sub-kernels, each run through the `F(m, r)`
//! core at its spatial offset and pad-accumulated — the
//! `K1K2/r²`-rounds structure of Eq. 12.

use super::tensor::{Mat, Tensor, Weights};
use crate::graph::layer::ConvSpec;

/// m=2, r=3 transform matrices as `Mat`s.
fn bt() -> Mat {
    Mat {
        rows: 4,
        cols: 4,
        data: vec![
            1.0, 0.0, -1.0, 0.0, //
            0.0, 1.0, 1.0, 0.0, //
            0.0, -1.0, 1.0, 0.0, //
            0.0, 1.0, 0.0, -1.0,
        ],
    }
}

fn g() -> Mat {
    Mat {
        rows: 4,
        cols: 3,
        data: vec![
            1.0, 0.0, 0.0, //
            0.5, 0.5, 0.5, //
            0.5, -0.5, 0.5, //
            0.0, 0.0, 1.0,
        ],
    }
}

fn at() -> Mat {
    Mat {
        rows: 2,
        cols: 4,
        data: vec![
            1.0, 1.0, 1.0, 0.0, //
            0.0, 1.0, -1.0, -1.0,
        ],
    }
}

/// Transform one 3×3 kernel: `U = G g Gᵀ` (4×4).
pub fn transform_kernel(k3: &Mat) -> Mat {
    debug_assert_eq!((k3.rows, k3.cols), (3, 3));
    let g_ = g();
    g_.matmul(k3).matmul(&g_.transposed())
}

/// Transform one 4×4 input tile: `V = Bᵀ d B`.
pub fn transform_input(d: &Mat) -> Mat {
    debug_assert_eq!((d.rows, d.cols), (4, 4));
    let bt_ = bt();
    bt_.matmul(d).matmul(&bt_.transposed())
}

/// Inverse-transform one 4×4 accumulated tile: `Y = Aᵀ M A` (2×2).
pub fn inverse_transform(m_: &Mat) -> Mat {
    let at_ = at();
    at_.matmul(m_).matmul(&at_.transposed())
}

/// Winograd convolution for any square kernel `K ≥ 3`, stride 1.
/// `K > 3` decomposes into `⌈K/3⌉²` 3×3 sub-kernels (zero-padded at the
/// boundary), each producing a partial conv at its offset.
pub fn conv2d(input: &Tensor, weights: &Weights, spec: &ConvSpec) -> Tensor {
    assert_eq!(spec.k1, spec.k2, "winograd needs a square kernel");
    assert_eq!(spec.s, 1, "winograd core is stride-1 (see conv2d_strided)");
    assert!(spec.k1 >= 3, "winograd needs K ≥ r = 3");
    let k = spec.k1;
    let groups = k.div_ceil(3);
    let mut total = Tensor::zeros(spec.c_out, spec.o1(), spec.o2());
    for gy in 0..groups {
        for gx in 0..groups {
            // sub-kernel (3×3, zero-padded past K)
            let mut sub = Weights::zeros(spec.c_out, spec.c_in, 3, 3);
            for co in 0..spec.c_out {
                for ci in 0..spec.c_in {
                    for dy in 0..3 {
                        for dx in 0..3 {
                            let ky = gy * 3 + dy;
                            let kx = gx * 3 + dx;
                            if ky < k && kx < k {
                                sub.set(co, ci, dy, dx, weights.get(co, ci, ky, kx));
                            }
                        }
                    }
                }
            }
            let sub_spec = ConvSpec::new(
                spec.c_in, spec.c_out, spec.h1, spec.h2, 3, 3, 1, spec.p1, spec.p2,
            );
            // sub-kernel taps sit at +$(gy·3, gx·3)$ relative to the full
            // kernel origin → shift the input window accordingly
            let mut sub_spec2 = sub_spec.clone();
            // output dims must match the full conv's output
            sub_spec2.h1 = spec.h1;
            sub_spec2.h2 = spec.h2;
            let partial = conv3x3_f23_with_odims(
                input,
                &sub,
                &sub_spec2,
                ((gy * 3) as isize, (gx * 3) as isize),
                spec.o1(),
                spec.o2(),
            );
            for i in 0..total.data.len() {
                total.data[i] += partial.data[i];
            }
        }
    }
    total
}

/// Like [`conv3x3_f23`] but forcing the output dims of the *full*
/// kernel's conv (partial sub-kernel convs all share those dims).
fn conv3x3_f23_with_odims(
    input: &Tensor,
    weights: &Weights,
    spec: &ConvSpec,
    shift: (isize, isize),
    o1: usize,
    o2: usize,
) -> Tensor {
    let t1 = o1.div_ceil(2);
    let t2 = o2.div_ceil(2);
    let mut out = Tensor::zeros(spec.c_out, o1, o2);
    let mut u = vec![Mat::zeros(4, 4); spec.c_out * weights.c_in];
    for co in 0..spec.c_out {
        for ci in 0..weights.c_in {
            let k3 = Mat::from_fn(3, 3, |y, x| weights.get(co, ci, y, x));
            u[co * weights.c_in + ci] = transform_kernel(&k3);
        }
    }
    for ty in 0..t1 {
        for tx in 0..t2 {
            let iy0 = (ty * 2) as isize - spec.p1 as isize + shift.0;
            let ix0 = (tx * 2) as isize - spec.p2 as isize + shift.1;
            let mut v = Vec::with_capacity(input.c);
            for ci in 0..input.c {
                let d = Mat::from_fn(4, 4, |y, x| {
                    input.get_padded(ci, iy0 + y as isize, ix0 + x as isize)
                });
                v.push(transform_input(&d));
            }
            for co in 0..spec.c_out {
                let mut m_acc = Mat::zeros(4, 4);
                for ci in 0..input.c {
                    let u_ = &u[co * input.c + ci];
                    let v_ = &v[ci];
                    for i in 0..16 {
                        m_acc.data[i] += u_.data[i] * v_.data[i];
                    }
                }
                let y = inverse_transform(&m_acc);
                for dy in 0..2 {
                    for dx in 0..2 {
                        let oy = ty * 2 + dy;
                        let ox = tx * 2 + dx;
                        if oy < o1 && ox < o2 {
                            let cur = out.get(co, oy, ox);
                            out.set(co, oy, ox, cur + y.get(dy, dx));
                        }
                    }
                }
            }
        }
    }
    out
}

/// Strided-Winograd extension (paper §7 future work): a stride-2 square
/// conv is split into 4 stride-1 sub-convolutions over the even/odd
/// polyphase components of input and kernel, each handled by the
/// stride-1 path, with results summed.
pub fn conv2d_strided(input: &Tensor, weights: &Weights, spec: &ConvSpec) -> Tensor {
    assert_eq!(spec.s, 2, "conv2d_strided handles stride 2");
    assert_eq!(spec.k1, spec.k2, "square kernels only");
    // Fall back to exact reference semantics via polyphase decomposition:
    // out(oy,ox) = Σ_{ky,kx} w(ky,kx)·in(2oy+ky−p, 2ox+kx−p)
    // Split taps by parity of (ky, kx): each parity class is a stride-1
    // conv on the corresponding input phase. For the class kernels we use
    // the direct (non-Winograd) path when the sub-kernel is < 3 wide —
    // the decomposition's value here is functional validation of the
    // extension's data path.
    let (o1, o2) = (spec.o1(), spec.o2());
    let mut out = Tensor::zeros(spec.c_out, o1, o2);
    for py in 0..2usize {
        for px in 0..2usize {
            // input phase (py, px): in_ph(y, x) = in(2y + py, 2x + px)
            let ph_h = (spec.h1 + 2 * spec.p1).div_ceil(2);
            let ph_w = (spec.h2 + 2 * spec.p2).div_ceil(2);
            let phase = Tensor::from_fn(spec.c_in, ph_h, ph_w, |c, y, x| {
                let iy = (2 * y + py) as isize - spec.p1 as isize;
                let ix = (2 * x + px) as isize - spec.p2 as isize;
                input.get_padded(c, iy, ix)
            });
            // kernel phase: taps with ky ≡ py, kx ≡ px (mod 2)
            // taps 2·ky + py < K → kk = ⌈(K − p)/2⌉ per dimension
            let kk1 = (spec.k1 - py).div_ceil(2);
            let kk2 = (spec.k2 - px).div_ceil(2);
            if kk1 == 0 || kk2 == 0 {
                continue;
            }
            let mut wk = Weights::zeros(spec.c_out, spec.c_in, kk1, kk2);
            for co in 0..spec.c_out {
                for ci in 0..spec.c_in {
                    for ky in 0..kk1 {
                        for kx in 0..kk2 {
                            wk.set(co, ci, ky, kx, weights.get(co, ci, 2 * ky + py, 2 * kx + px));
                        }
                    }
                }
            }
            let sub_spec =
                ConvSpec::new(spec.c_in, spec.c_out, ph_h, ph_w, kk1, kk2, 1, 0, 0);
            let partial = super::direct::conv2d(&phase, &wk, &sub_spec);
            // accumulate the overlapping top-left region
            for co in 0..spec.c_out {
                for oy in 0..o1.min(partial.h) {
                    for ox in 0..o2.min(partial.w) {
                        let cur = out.get(co, oy, ox);
                        out.set(co, oy, ox, cur + partial.get(co, oy, ox));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::direct;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    #[test]
    fn f23_identity_on_known_values() {
        // winograd of a delta kernel = input crop
        let spec = ConvSpec::new(1, 1, 6, 6, 3, 3, 1, 1, 1);
        let input = Tensor::from_fn(1, 6, 6, |_, y, x| (y * 6 + x) as f32);
        let mut w = Weights::zeros(1, 1, 3, 3);
        w.set(0, 0, 1, 1, 1.0); // center tap → identity with same padding
        let out = conv2d(&input, &w, &spec);
        assert_allclose(&out.data, &input.data, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn matches_direct_3x3() {
        let spec = ConvSpec::new(3, 2, 8, 8, 3, 3, 1, 1, 1);
        let mut rng = Rng::new(11);
        let input = Tensor::random(3, 8, 8, &mut rng);
        let w = Weights::random(2, 3, 3, 3, &mut rng);
        let a = direct::conv2d(&input, &w, &spec);
        let b = conv2d(&input, &w, &spec);
        assert_allclose(&b.data, &a.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn matches_direct_5x5_decomposed() {
        // 5×5 kernels take the ⌈K/3⌉² = 4-round decomposition (Eq. 12)
        let spec = ConvSpec::new(2, 2, 9, 9, 5, 5, 1, 2, 2);
        let mut rng = Rng::new(12);
        let input = Tensor::random(2, 9, 9, &mut rng);
        let w = Weights::random(2, 2, 5, 5, &mut rng);
        let a = direct::conv2d(&input, &w, &spec);
        let b = conv2d(&input, &w, &spec);
        assert_allclose(&b.data, &a.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn odd_output_dims() {
        // O not a multiple of m: the last tile row/col is partial
        let spec = ConvSpec::new(1, 1, 7, 7, 3, 3, 1, 0, 0); // O = 5×5
        let mut rng = Rng::new(13);
        let input = Tensor::random(1, 7, 7, &mut rng);
        let w = Weights::random(1, 1, 3, 3, &mut rng);
        let a = direct::conv2d(&input, &w, &spec);
        let b = conv2d(&input, &w, &spec);
        assert_eq!((b.h, b.w), (5, 5));
        assert_allclose(&b.data, &a.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn property_matches_direct() {
        check("winograd_vs_direct", 32, |r: &mut Rng| {
            let k = *r.choose(&[3usize, 5]);
            let h = r.range(k + 1, 11);
            let spec = ConvSpec::new(
                r.range(1, 3),
                r.range(1, 3),
                h,
                h,
                k,
                k,
                1,
                k / 2,
                k / 2,
            );
            let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random(spec.c_out, spec.c_in, k, k, r);
            let a = direct::conv2d(&input, &w, &spec);
            let b = conv2d(&input, &w, &spec);
            assert_allclose(&b.data, &a.data, 1e-3, 1e-3)
                .map_err(|e| format!("spec {spec:?}: {e}"))
        });
    }

    #[test]
    fn strided_extension_matches_direct() {
        check("strided_wino_vs_direct", 24, |r: &mut Rng| {
            let k = *r.choose(&[3usize, 5]);
            let h = r.range(k + 2, 12);
            let spec =
                ConvSpec::new(r.range(1, 3), r.range(1, 3), h, h, k, k, 2, k / 2, k / 2);
            let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random(spec.c_out, spec.c_in, k, k, r);
            let a = direct::conv2d(&input, &w, &spec);
            let b = conv2d_strided(&input, &w, &spec);
            if (a.h, a.w) != (b.h, b.w) {
                return Err(format!("dims {:?} vs {:?} for {spec:?}", (a.h, a.w), (b.h, b.w)));
            }
            assert_allclose(&b.data, &a.data, 1e-3, 1e-3)
                .map_err(|e| format!("spec {spec:?}: {e}"))
        });
    }
}
