//! INT8 fixed-point arithmetic — the paper evaluates with 8-bit
//! fixed-point data (§6), one DSP per MAC.
//!
//! Symmetric per-tensor quantization: `q = round(x / scale)` clamped to
//! `[-127, 127]`, accumulation in i32 (the DSP48 accumulator), output
//! re-quantized with the product scale. im2col and kn2row perform the
//! same multiplies in the same ring, so their INT8 outputs are
//! bit-identical; Winograd transforms need the wider intermediate
//! (the hardware runs them in 16-bit shift-add, §3.1).

use super::tensor::{Tensor, Weights};
use crate::graph::layer::ConvSpec;

/// A quantized tensor: i8 payload + scale.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub scale: f32,
    pub data: Vec<i8>,
}

/// Quantized weights.
#[derive(Debug, Clone, PartialEq)]
pub struct QWeights {
    pub c_out: usize,
    pub c_in: usize,
    pub k1: usize,
    pub k2: usize,
    pub scale: f32,
    pub data: Vec<i8>,
}

/// Max-abs symmetric scale.
pub fn scale_for(xs: &[f32]) -> f32 {
    let m = xs.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    if m == 0.0 {
        1.0
    } else {
        m / 127.0
    }
}

pub fn quantize_tensor(t: &Tensor) -> QTensor {
    let scale = scale_for(&t.data);
    QTensor {
        c: t.c,
        h: t.h,
        w: t.w,
        scale,
        data: t.data.iter().map(|&x| quant(x, scale)).collect(),
    }
}

pub fn quantize_weights(w: &Weights) -> QWeights {
    let scale = scale_for(&w.data);
    QWeights {
        c_out: w.c_out,
        c_in: w.c_in,
        k1: w.k1,
        k2: w.k2,
        scale,
        data: w.data.iter().map(|&x| quant(x, scale)).collect(),
    }
}

#[inline]
fn quant(x: f32, scale: f32) -> i8 {
    let q = (x / scale).round();
    q.clamp(-127.0, 127.0) as i8
}

impl QTensor {
    #[inline]
    pub fn get_padded(&self, c: usize, y: isize, x: isize) -> i32 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0
        } else {
            self.data[(c * self.h + y as usize) * self.w + x as usize] as i32
        }
    }

    /// Dequantize back to f32.
    pub fn dequant(&self) -> Tensor {
        Tensor {
            c: self.c,
            h: self.h,
            w: self.w,
            data: self.data.iter().map(|&q| q as f32 * self.scale).collect(),
        }
    }
}

impl QWeights {
    #[inline]
    pub fn get(&self, co: usize, ci: usize, ky: usize, kx: usize) -> i32 {
        self.data[((co * self.c_in + ci) * self.k1 + ky) * self.k2 + kx] as i32
    }
}

/// INT8 direct convolution with i32 accumulation; output is an i32
/// tensor with scale `in.scale · w.scale` (re-quantization is the
/// caller's choice — the engine keeps 32-bit partials like the
/// accumulation buffer in the overlay).
pub fn conv2d_i32(input: &QTensor, weights: &QWeights, spec: &ConvSpec) -> Vec<i32> {
    let (o1, o2) = (spec.o1(), spec.o2());
    let mut out = vec![0i32; spec.c_out * o1 * o2];
    for co in 0..spec.c_out {
        for oy in 0..o1 {
            for ox in 0..o2 {
                let mut acc = 0i32;
                for ci in 0..spec.c_in {
                    for ky in 0..spec.k1 {
                        for kx in 0..spec.k2 {
                            let iy = (oy * spec.s + ky) as isize - spec.p1 as isize;
                            let ix = (ox * spec.s + kx) as isize - spec.p2 as isize;
                            acc += weights.get(co, ci, ky, kx) * input.get_padded(ci, iy, ix);
                        }
                    }
                }
                out[(co * o1 + oy) * o2 + ox] = acc;
            }
        }
    }
    out
}

/// INT8 kn2row: unit-conv GEMMs in i32 + pad-accumulate. Must be
/// bit-identical to [`conv2d_i32`].
pub fn conv2d_i32_kn2row(input: &QTensor, weights: &QWeights, spec: &ConvSpec) -> Vec<i32> {
    let (o1, o2) = (spec.o1(), spec.o2());
    let mut out = vec![0i32; spec.c_out * o1 * o2];
    for ky in 0..spec.k1 {
        for kx in 0..spec.k2 {
            // unit conv patch: C_out × H1H2
            let mut patch = vec![0i32; spec.c_out * spec.h1 * spec.h2];
            for co in 0..spec.c_out {
                for ci in 0..spec.c_in {
                    let w = weights.get(co, ci, ky, kx);
                    if w == 0 {
                        continue;
                    }
                    for y in 0..spec.h1 {
                        for x in 0..spec.h2 {
                            patch[(co * spec.h1 + y) * spec.h2 + x] +=
                                w * input.get_padded(ci, y as isize, x as isize);
                        }
                    }
                }
            }
            // pad-accumulate
            for co in 0..spec.c_out {
                for oy in 0..o1 {
                    for ox in 0..o2 {
                        let iy = (oy * spec.s + ky) as isize - spec.p1 as isize;
                        let ix = (ox * spec.s + kx) as isize - spec.p2 as isize;
                        if iy < 0 || ix < 0 || iy >= spec.h1 as isize || ix >= spec.h2 as isize {
                            continue;
                        }
                        out[(co * o1 + oy) * o2 + ox] +=
                            patch[(co * spec.h1 + iy as usize) * spec.h2 + ix as usize];
                    }
                }
            }
        }
    }
    out
}

/// Relative quantization error of the INT8 path vs an f32 reference —
/// used to assert the INT8 design stays within CNN-tolerable error.
pub fn rel_error(q_out: &[i32], scale: f32, f_ref: &[f32]) -> f32 {
    assert_eq!(q_out.len(), f_ref.len());
    let mut num = 0.0f32;
    let mut den = 1e-12f32;
    for (&q, &r) in q_out.iter().zip(f_ref) {
        let x = q as f32 * scale;
        num += (x - r) * (x - r);
        den += r * r;
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::direct;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn quant_roundtrip_small_ints() {
        // integers ≤127 with scale 1 survive exactly
        let t = Tensor::from_fn(1, 2, 2, |_, y, x| (y as f32 * 2.0 + x as f32) - 1.0);
        let q = quantize_tensor(&t);
        let back = q.dequant();
        for (a, b) in t.data.iter().zip(&back.data) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn im2col_equals_kn2row_bit_exact() {
        check("int8_kn2row_exact", 48, |r: &mut Rng| {
            let spec = crate::algos::im2col::random_spec(r);
            let input = quantize_tensor(&Tensor::random(spec.c_in, spec.h1, spec.h2, r));
            let w = quantize_weights(&Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, r));
            let a = conv2d_i32(&input, &w, &spec);
            let b = conv2d_i32_kn2row(&input, &w, &spec);
            if a != b {
                return Err(format!("INT8 direct vs kn2row mismatch for {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn int8_error_is_small() {
        let spec = ConvSpec::new(4, 4, 8, 8, 3, 3, 1, 1, 1);
        let mut rng = Rng::new(21);
        let fin = Tensor::random(4, 8, 8, &mut rng);
        let fw = Weights::random(4, 4, 3, 3, &mut rng);
        let fref = direct::conv2d(&fin, &fw, &spec);
        let qi = quantize_tensor(&fin);
        let qw = quantize_weights(&fw);
        let qo = conv2d_i32(&qi, &qw, &spec);
        let err = rel_error(&qo, qi.scale * qw.scale, &fref.data);
        assert!(err < 0.05, "INT8 relative error {err}");
    }
}
