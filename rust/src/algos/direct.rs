//! Direct (sliding-window) spatial convolution — the oracle (Eq. 1).

use super::tensor::{Tensor, Weights};
use crate::graph::layer::ConvSpec;

/// Direct convolution of `input` (`c_in × h1 × h2`) with `weights`,
/// stride `s` and symmetric zero padding `(p1, p2)`.
pub fn conv2d(input: &Tensor, weights: &Weights, spec: &ConvSpec) -> Tensor {
    assert_eq!(input.c, spec.c_in);
    assert_eq!(input.h, spec.h1);
    assert_eq!(input.w, spec.h2);
    assert_eq!(weights.c_out, spec.c_out);
    assert_eq!(weights.c_in, spec.c_in);
    assert_eq!((weights.k1, weights.k2), (spec.k1, spec.k2));
    let (o1, o2) = (spec.o1(), spec.o2());
    let mut out = Tensor::zeros(spec.c_out, o1, o2);
    for co in 0..spec.c_out {
        for oy in 0..o1 {
            for ox in 0..o2 {
                let mut acc = 0.0f32;
                for ci in 0..spec.c_in {
                    for ky in 0..spec.k1 {
                        for kx in 0..spec.k2 {
                            let iy = (oy * spec.s + ky) as isize - spec.p1 as isize;
                            let ix = (ox * spec.s + kx) as isize - spec.p2 as isize;
                            acc += weights.get(co, ci, ky, kx) * input.get_padded(ci, iy, ix);
                        }
                    }
                }
                out.set(co, oy, ox, acc);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel() {
        // 1×1 kernel of 1.0 reproduces the input
        let spec = ConvSpec::new(1, 1, 4, 4, 1, 1, 1, 0, 0);
        let input = Tensor::from_fn(1, 4, 4, |_, y, x| (y * 4 + x) as f32);
        let mut w = Weights::zeros(1, 1, 1, 1);
        w.set(0, 0, 0, 0, 1.0);
        let out = conv2d(&input, &w, &spec);
        assert_eq!(out.data, input.data);
    }

    #[test]
    fn box_filter() {
        // 3×3 all-ones kernel on all-ones input, same padding: interior
        // pixels see 9, corners 4, edges 6
        let spec = ConvSpec::new(1, 1, 4, 4, 3, 3, 1, 1, 1);
        let input = Tensor::from_fn(1, 4, 4, |_, _, _| 1.0);
        let mut w = Weights::zeros(1, 1, 3, 3);
        for v in &mut w.data {
            *v = 1.0;
        }
        let out = conv2d(&input, &w, &spec);
        assert_eq!(out.get(0, 1, 1), 9.0);
        assert_eq!(out.get(0, 0, 0), 4.0);
        assert_eq!(out.get(0, 0, 1), 6.0);
    }

    #[test]
    fn stride_two() {
        let spec = ConvSpec::new(1, 1, 4, 4, 1, 1, 2, 0, 0);
        let input = Tensor::from_fn(1, 4, 4, |_, y, x| (y * 4 + x) as f32);
        let mut w = Weights::zeros(1, 1, 1, 1);
        w.set(0, 0, 0, 0, 1.0);
        let out = conv2d(&input, &w, &spec);
        assert_eq!((out.h, out.w), (2, 2));
        assert_eq!(out.data, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn channel_summation() {
        // two input channels of constant 1 and 2, kernel weights 1 → 3
        let spec = ConvSpec::new(2, 1, 3, 3, 1, 1, 1, 0, 0);
        let input = Tensor::from_fn(2, 3, 3, |c, _, _| (c + 1) as f32);
        let mut w = Weights::zeros(1, 2, 1, 1);
        w.set(0, 0, 0, 0, 1.0);
        w.set(0, 1, 0, 0, 1.0);
        let out = conv2d(&input, &w, &spec);
        assert!(out.data.iter().all(|&v| v == 3.0));
    }
}
