//! Functional (bit-accurate) implementations of the three GEMM-based
//! convolution families (§2.1) in f32 and INT8 fixed point.
//!
//! These are the numerical ground truth the overlay simulator and the
//! PJRT artifacts are validated against: [`direct`] is the sliding-window
//! oracle (Eq. 1); [`im2col`], [`kn2row`] and [`winograd`] must agree
//! with it exactly (f32 up to rounding, INT8 bit-exactly for im2col vs
//! kn2row since both perform the same multiplies).

pub mod tensor;
pub mod direct;
pub mod im2col;
pub mod kn2row;
pub mod winograd;
pub mod fft;
pub mod fixed;

pub use tensor::{Mat, Tensor};
