//! kn2row convolution (§2.1.2): `K1K2` unit (1×1) convolutions —
//! GEMMs `W (C_out × C_in) × X (C_in × H1H2)` — whose intermediate
//! patches are shifted by their kernel offsets, zero-padded on the
//! non-overlap and Hadamard-added ("Pad-and-Accumulate", Eq. 4).

use super::tensor::{Mat, Tensor, Weights};
use crate::graph::layer::ConvSpec;

/// The `C_in × H1H2` input matrix of the unit-convolution GEMM — the
/// plain 3D-tensor layout, no duplication (the algorithm's selling
/// point: low memory).
pub fn input_matrix(input: &Tensor) -> Mat {
    Mat { rows: input.c, cols: input.h * input.w, data: input.data.clone() }
}

/// Weight matrix of the `(k1, k2)` unit convolution: `C_out × C_in`.
pub fn unit_weight_matrix(weights: &Weights, ky: usize, kx: usize) -> Mat {
    Mat::from_fn(weights.c_out, weights.c_in, |co, ci| weights.get(co, ci, ky, kx))
}

/// One intermediate patch `p_{k1,k2}` (Eq. 3) as a `C_out × H1H2` GEMM
/// output.
pub fn unit_conv(input: &Tensor, weights: &Weights, ky: usize, kx: usize) -> Mat {
    unit_weight_matrix(weights, ky, kx).matmul(&input_matrix(input))
}

/// Pad-and-Accumulate (Eq. 4): shift patch `(ky, kx)` by its offset
/// relative to the kernel center and accumulate into `acc`
/// (`C_out × O1 × O2`), honouring stride and padding.
///
/// For output pixel `(oy, ox)`, the unit-conv contribution of kernel tap
/// `(ky, kx)` is the patch value at input coordinate
/// `(oy·s + ky − p1, ox·s + kx − p2)` — i.e. the accumulation walks the
/// patch with a per-tap offset, which is exactly the paper's
/// "shift + pad with 0 on non-overlapping areas".
pub fn pad_accumulate(
    acc: &mut Tensor,
    patch: &Mat,
    spec: &ConvSpec,
    ky: usize,
    kx: usize,
) {
    let (o1, o2) = (spec.o1(), spec.o2());
    debug_assert_eq!((acc.c, acc.h, acc.w), (spec.c_out, o1, o2));
    debug_assert_eq!(patch.rows, spec.c_out);
    debug_assert_eq!(patch.cols, spec.h1 * spec.h2);
    for co in 0..spec.c_out {
        for oy in 0..o1 {
            for ox in 0..o2 {
                let iy = (oy * spec.s + ky) as isize - spec.p1 as isize;
                let ix = (ox * spec.s + kx) as isize - spec.p2 as isize;
                if iy < 0 || ix < 0 || iy >= spec.h1 as isize || ix >= spec.h2 as isize {
                    continue; // zero padding
                }
                let v = patch.get(co, iy as usize * spec.h2 + ix as usize);
                let cur = acc.get(co, oy, ox);
                acc.set(co, oy, ox, cur + v);
            }
        }
    }
}

/// [`pad_accumulate`] for a patch in its GEMM-native transposed
/// `H1H2 × C_out` orientation (the systolic array and the kernel layer
/// both produce `Xᵀ·Wᵀ` outputs) — accumulating straight from `patchᵀ`
/// deletes the per-tap transpose the old path paid.
pub fn pad_accumulate_t(
    acc: &mut Tensor,
    patch_t: &Mat,
    spec: &ConvSpec,
    ky: usize,
    kx: usize,
) {
    let (o1, o2) = (spec.o1(), spec.o2());
    debug_assert_eq!((acc.c, acc.h, acc.w), (spec.c_out, o1, o2));
    debug_assert_eq!(patch_t.rows, spec.h1 * spec.h2);
    debug_assert_eq!(patch_t.cols, spec.c_out);
    let c_out = spec.c_out;
    for oy in 0..o1 {
        let iy = (oy * spec.s + ky) as isize - spec.p1 as isize;
        if iy < 0 || iy >= spec.h1 as isize {
            continue; // whole output row falls on the zero pad
        }
        for ox in 0..o2 {
            let ix = (ox * spec.s + kx) as isize - spec.p2 as isize;
            if ix < 0 || ix >= spec.h2 as isize {
                continue;
            }
            let row = (iy as usize * spec.h2 + ix as usize) * c_out;
            let vals = &patch_t.data[row..row + c_out];
            for (co, &v) in vals.iter().enumerate() {
                acc.data[(co * o1 + oy) * o2 + ox] += v;
            }
        }
    }
}

/// kn2row convolution: K1K2 unit-conv GEMMs + Pad-and-Accumulate.
pub fn conv2d(input: &Tensor, weights: &Weights, spec: &ConvSpec) -> Tensor {
    let mut acc = Tensor::zeros(spec.c_out, spec.o1(), spec.o2());
    for ky in 0..spec.k1 {
        for kx in 0..spec.k2 {
            let patch = unit_conv(input, weights, ky, kx);
            pad_accumulate(&mut acc, &patch, spec, ky, kx);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{direct, im2col};
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn matches_direct_3x3() {
        let spec = ConvSpec::new(2, 3, 6, 6, 3, 3, 1, 1, 1);
        let mut rng = Rng::new(5);
        let input = Tensor::random_i8(2, 6, 6, &mut rng);
        let w = Weights::random_i8(3, 2, 3, 3, &mut rng);
        let a = direct::conv2d(&input, &w, &spec);
        let b = conv2d(&input, &w, &spec);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn matches_direct_1x7() {
        // the Inception-v4 factorized kernel shape
        let spec = ConvSpec::new(2, 2, 9, 9, 1, 7, 1, 0, 3);
        let mut rng = Rng::new(6);
        let input = Tensor::random_i8(2, 9, 9, &mut rng);
        let w = Weights::random_i8(2, 2, 1, 7, &mut rng);
        assert_eq!(direct::conv2d(&input, &w, &spec).data, conv2d(&input, &w, &spec).data);
    }

    #[test]
    fn unit_conv_is_gemm_of_tap() {
        // for a 1×1 kernel, kn2row degenerates to exactly one GEMM
        let spec = ConvSpec::new(3, 4, 5, 5, 1, 1, 1, 0, 0);
        let mut rng = Rng::new(7);
        let input = Tensor::random_i8(3, 5, 5, &mut rng);
        let w = Weights::random_i8(4, 3, 1, 1, &mut rng);
        let patch = unit_conv(&input, &w, 0, 0);
        let out = conv2d(&input, &w, &spec);
        assert_eq!(patch.data, out.data);
    }

    #[test]
    fn property_matches_im2col() {
        check("kn2row_vs_im2col", 48, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            let input = Tensor::random_i8(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random_i8(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let a = im2col::conv2d(&input, &w, &spec);
            let b = conv2d(&input, &w, &spec);
            if a.data != b.data {
                return Err(format!("mismatch for spec {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pad_accumulate_t_matches_untransposed() {
        check("pad_accumulate_t", 32, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            let input = Tensor::random_i8(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random_i8(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let mut a = Tensor::zeros(spec.c_out, spec.o1(), spec.o2());
            let mut b = Tensor::zeros(spec.c_out, spec.o1(), spec.o2());
            for ky in 0..spec.k1 {
                for kx in 0..spec.k2 {
                    let patch = unit_conv(&input, &w, ky, kx);
                    pad_accumulate(&mut a, &patch, &spec, ky, kx);
                    pad_accumulate_t(&mut b, &patch.transposed(), &spec, ky, kx);
                }
            }
            if a.data != b.data {
                return Err(format!("transposed accumulate mismatch for {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn strided_kn2row() {
        let spec = ConvSpec::new(1, 1, 6, 6, 3, 3, 2, 1, 1);
        let mut rng = Rng::new(8);
        let input = Tensor::random_i8(1, 6, 6, &mut rng);
        let w = Weights::random_i8(1, 1, 3, 3, &mut rng);
        assert_eq!(direct::conv2d(&input, &w, &spec).data, conv2d(&input, &w, &spec).data);
    }
}
