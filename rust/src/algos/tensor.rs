//! Dense tensor / matrix containers used by the functional algorithms
//! and the overlay simulator.

use crate::util::rng::Rng;

/// A `C × H × W` tensor in CHW layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(c: usize, h: usize, w: usize) -> Tensor {
        Tensor { c, h, w, data: vec![0.0; c * h * w] }
    }

    pub fn from_fn(c: usize, h: usize, w: usize, mut f: impl FnMut(usize, usize, usize) -> f32) -> Tensor {
        let mut t = Tensor::zeros(c, h, w);
        for ci in 0..c {
            for y in 0..h {
                for x in 0..w {
                    t.data[(ci * h + y) * w + x] = f(ci, y, x);
                }
            }
        }
        t
    }

    pub fn random(c: usize, h: usize, w: usize, rng: &mut Rng) -> Tensor {
        Tensor::from_fn(c, h, w, |_, _, _| rng.f32_range(-1.0, 1.0))
    }

    /// Random small-integer tensor — exercises exact arithmetic paths.
    pub fn random_i8(c: usize, h: usize, w: usize, rng: &mut Rng) -> Tensor {
        Tensor::from_fn(c, h, w, |_, _, _| rng.i8_small() as f32)
    }

    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> f32 {
        self.data[(c * self.h + y) * self.w + x]
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, v: f32) {
        self.data[(c * self.h + y) * self.w + x] = v;
    }

    /// Zero-padded read: out-of-bounds coordinates return 0.
    #[inline]
    pub fn get_padded(&self, c: usize, y: isize, x: isize) -> f32 {
        if y < 0 || x < 0 || y >= self.h as isize || x >= self.w as isize {
            0.0
        } else {
            self.get(c, y as usize, x as usize)
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Convolution weights: `c_out × c_in × k1 × k2`.
#[derive(Debug, Clone, PartialEq)]
pub struct Weights {
    pub c_out: usize,
    pub c_in: usize,
    pub k1: usize,
    pub k2: usize,
    pub data: Vec<f32>,
}

impl Weights {
    pub fn zeros(c_out: usize, c_in: usize, k1: usize, k2: usize) -> Weights {
        Weights { c_out, c_in, k1, k2, data: vec![0.0; c_out * c_in * k1 * k2] }
    }

    pub fn random(c_out: usize, c_in: usize, k1: usize, k2: usize, rng: &mut Rng) -> Weights {
        let mut w = Weights::zeros(c_out, c_in, k1, k2);
        for v in &mut w.data {
            *v = rng.f32_range(-0.5, 0.5);
        }
        w
    }

    pub fn random_i8(c_out: usize, c_in: usize, k1: usize, k2: usize, rng: &mut Rng) -> Weights {
        let mut w = Weights::zeros(c_out, c_in, k1, k2);
        for v in &mut w.data {
            *v = rng.i8_small() as f32;
        }
        w
    }

    #[inline]
    pub fn get(&self, co: usize, ci: usize, ky: usize, kx: usize) -> f32 {
        self.data[((co * self.c_in + ci) * self.k1 + ky) * self.k2 + kx]
    }

    #[inline]
    pub fn set(&mut self, co: usize, ci: usize, ky: usize, kx: usize, v: f32) {
        self.data[((co * self.c_in + ci) * self.k1 + ky) * self.k2 + kx] = v;
    }
}

/// A dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Plain `self × other` matrix multiply. Dense inner loop with no
    /// data-dependent branches (a zero-skip here defeats
    /// autovectorization on dense data — see [`Mat::matmul_sparse`] for
    /// the skip-aware variant).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }

    /// `self × other` skipping zero left-hand entries — worthwhile only
    /// when `self` is genuinely sparse (e.g. zero-padded sub-kernel
    /// matrices); on dense data prefer [`Mat::matmul`].
    pub fn matmul_sparse(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.get(i, k);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * other.cols + j] += a * other.get(k, j);
                }
            }
        }
        out
    }

    pub fn transposed(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_indexing() {
        let t = Tensor::from_fn(2, 3, 4, |c, y, x| (c * 100 + y * 10 + x) as f32);
        assert_eq!(t.get(1, 2, 3), 123.0);
        assert_eq!(t.get_padded(1, -1, 0), 0.0);
        assert_eq!(t.get_padded(1, 2, 4), 0.0);
        assert_eq!(t.get_padded(1, 2, 3), 123.0);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        let b = Mat::from_fn(3, 2, |i, j| (i * 2 + j) as f32);
        assert_eq!(a.matmul(&b), b);
    }

    #[test]
    fn matmul_known() {
        let a = Mat { rows: 2, cols: 2, data: vec![1.0, 2.0, 3.0, 4.0] };
        let b = Mat { rows: 2, cols: 2, data: vec![1.0, 1.0, 1.0, 1.0] };
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_sparse_matches_dense() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(9);
        // mix of zeros and small ints: the skip path must not change
        // results on integer-valued data
        let a = Mat::from_fn(6, 7, |_, _| if r.bool() { 0.0 } else { r.i8_small() as f32 });
        let b = Mat::from_fn(7, 5, |_, _| r.i8_small() as f32);
        assert_eq!(a.matmul(&b).data, a.matmul_sparse(&b).data);
    }

    #[test]
    fn weights_indexing() {
        let mut w = Weights::zeros(2, 3, 3, 3);
        w.set(1, 2, 0, 1, 7.0);
        assert_eq!(w.get(1, 2, 0, 1), 7.0);
    }
}
