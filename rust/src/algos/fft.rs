//! Frequency-domain convolution — the second §7 future-work extension
//! ("we will explore ... frequency-domain methods").
//!
//! Conv-as-pointwise-product: zero-pad feature map and kernel to a
//! common power-of-two grid, 2-D FFT both, multiply per (c_in → c_out)
//! pair accumulating over channels in the frequency domain (the same
//! reduce-before-inverse-transform trick Winograd uses, Eq. 6), inverse
//! FFT once per output channel, crop with stride. Radix-2
//! Cooley–Tukey, no external deps.

use super::tensor::{Tensor, Weights};
use crate::graph::layer::ConvSpec;

/// Complex number (no external crates offline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cpx {
    pub re: f32,
    pub im: f32,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };

    #[inline]
    pub fn mul(self, o: Cpx) -> Cpx {
        Cpx { re: self.re * o.re - self.im * o.im, im: self.re * o.im + self.im * o.re }
    }

    #[inline]
    pub fn add(self, o: Cpx) -> Cpx {
        Cpx { re: self.re + o.re, im: self.im + o.im }
    }

    #[inline]
    pub fn sub(self, o: Cpx) -> Cpx {
        Cpx { re: self.re - o.re, im: self.im - o.im }
    }
}

/// In-place radix-2 DIT FFT. `n` must be a power of two.
/// `inverse` applies the conjugate transform WITHOUT the 1/n scale
/// (callers scale once at the end of the 2-D inverse).
pub fn fft_1d(buf: &mut [Cpx], inverse: bool) {
    let n = buf.len();
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    if n <= 1 {
        return;
    }
    // bit-reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            buf.swap(i, j);
        }
    }
    let sign = if inverse { 1.0f64 } else { -1.0f64 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wl = Cpx { re: ang.cos() as f32, im: ang.sin() as f32 };
        for start in (0..n).step_by(len) {
            let mut w = Cpx { re: 1.0, im: 0.0 };
            for k in 0..len / 2 {
                let u = buf[start + k];
                let v = buf[start + k + len / 2].mul(w);
                buf[start + k] = u.add(v);
                buf[start + k + len / 2] = u.sub(v);
                w = w.mul(wl);
            }
        }
        len <<= 1;
    }
}

/// 2-D FFT over an `n × n` row-major grid.
pub fn fft_2d(grid: &mut [Cpx], n: usize, inverse: bool) {
    assert_eq!(grid.len(), n * n);
    let mut col = vec![Cpx::ZERO; n];
    for r in 0..n {
        fft_1d(&mut grid[r * n..(r + 1) * n], inverse);
    }
    for c in 0..n {
        for r in 0..n {
            col[r] = grid[r * n + c];
        }
        fft_1d(&mut col, inverse);
        for r in 0..n {
            grid[r * n + c] = col[r];
        }
    }
    if inverse {
        let scale = 1.0 / (n * n) as f32;
        for v in grid.iter_mut() {
            v.re *= scale;
            v.im *= scale;
        }
    }
}

/// FFT grid side for a layer: padded input and kernel must fit with
/// linear (non-circular) convolution intact.
pub fn grid_side(spec: &ConvSpec) -> usize {
    let need = (spec.h1 + 2 * spec.p1 + spec.k1 - 1)
        .max(spec.h2 + 2 * spec.p2 + spec.k2 - 1);
    need.next_power_of_two()
}

/// Frequency-domain convolution; same contract as `direct::conv2d`.
pub fn conv2d(input: &Tensor, weights: &Weights, spec: &ConvSpec) -> Tensor {
    let n = grid_side(spec);
    let (o1, o2) = (spec.o1(), spec.o2());

    // forward-FFT all input channels once (re-used by every c_out)
    let mut x_hat = vec![vec![Cpx::ZERO; n * n]; spec.c_in];
    for (ci, chan) in x_hat.iter_mut().enumerate() {
        for y in 0..spec.h1 {
            for x in 0..spec.h2 {
                chan[(y + spec.p1) * n + (x + spec.p2)] =
                    Cpx { re: input.get(ci, y, x), im: 0.0 };
            }
        }
        fft_2d(chan, n, false);
    }

    let mut out = Tensor::zeros(spec.c_out, o1, o2);
    let mut k_hat = vec![Cpx::ZERO; n * n];
    let mut acc = vec![Cpx::ZERO; n * n];
    for co in 0..spec.c_out {
        for v in acc.iter_mut() {
            *v = Cpx::ZERO;
        }
        for ci in 0..spec.c_in {
            // CNN "convolution" is cross-correlation; circular FFT
            // convolution of the FLIPPED kernel yields it:
            //   y(t) = Σ_j k(j)·x(t − (K−1) + j)  ⇒ crop at t = o·s + K−1
            for v in k_hat.iter_mut() {
                *v = Cpx::ZERO;
            }
            for ky in 0..spec.k1 {
                for kx in 0..spec.k2 {
                    k_hat[(spec.k1 - 1 - ky) * n + (spec.k2 - 1 - kx)] =
                        Cpx { re: weights.get(co, ci, ky, kx), im: 0.0 };
                }
            }
            fft_2d(&mut k_hat, n, false);
            // frequency-domain channel reduction (Eq. 6 analogue)
            for i in 0..n * n {
                acc[i] = acc[i].add(x_hat[ci][i].mul(k_hat[i]));
            }
        }
        fft_2d(&mut acc, n, true);
        // crop: output pixel (oy, ox) sits at grid
        // (oy·s + K1 − 1, ox·s + K2 − 1) — see kernel placement above.
        for oy in 0..o1 {
            for ox in 0..o2 {
                let gy = oy * spec.s + spec.k1 - 1;
                let gx = ox * spec.s + spec.k2 - 1;
                out.set(co, oy, ox, acc[gy * n + gx].re);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::direct;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    #[test]
    fn fft_roundtrip() {
        check("fft_roundtrip", 32, |r: &mut Rng| {
            let n = 1 << r.range(1, 5);
            let orig: Vec<Cpx> =
                (0..n).map(|_| Cpx { re: r.f32_range(-1.0, 1.0), im: 0.0 }).collect();
            let mut buf = orig.clone();
            fft_1d(&mut buf, false);
            fft_1d(&mut buf, true);
            for (a, b) in buf.iter().zip(&orig) {
                if (a.re / n as f32 - b.re).abs() > 1e-4 {
                    return Err(format!("roundtrip mismatch n={n}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn parseval_sanity() {
        // FFT of a delta is flat ones
        let mut buf = vec![Cpx::ZERO; 8];
        buf[0] = Cpx { re: 1.0, im: 0.0 };
        fft_1d(&mut buf, false);
        for v in &buf {
            assert!((v.re - 1.0).abs() < 1e-5 && v.im.abs() < 1e-5);
        }
    }

    #[test]
    fn matches_direct_conv() {
        check("fft_conv_vs_direct", 24, |r: &mut Rng| {
            let k = *r.choose(&[1usize, 3, 5, 7]);
            let h = r.range(k.max(4), 12);
            let s = r.range(1, 2);
            let spec = crate::graph::layer::ConvSpec::new(
                r.range(1, 3),
                r.range(1, 3),
                h,
                h,
                k,
                k,
                s,
                k / 2,
                k / 2,
            );
            let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let a = direct::conv2d(&input, &w, &spec);
            let b = conv2d(&input, &w, &spec);
            assert_allclose(&b.data, &a.data, 5e-3, 5e-3)
                .map_err(|e| format!("spec {spec:?}: {e}"))
        });
    }

    #[test]
    fn grid_side_covers_linear_conv() {
        let spec = crate::graph::layer::ConvSpec::new(1, 1, 17, 17, 7, 7, 1, 3, 3);
        assert_eq!(grid_side(&spec), 32); // 17+6+6 = 29 → 32
    }
}
