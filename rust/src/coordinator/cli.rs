//! `simulate` and `infer` CLI subcommands.

use crate::api::{Compiler, Session};
use crate::cost::graph_build::Policy;
use crate::util::cli::Args;
use crate::util::table::Table;

/// `dynamap simulate --model mini-inception` — run the cycle-level
/// overlay simulator on every conv layer of a (small) model under its
/// DSE-chosen mapping and cross-check measured vs analytical cycles.
pub fn simulate(args: &Args) -> i32 {
    use crate::algos::tensor::{Tensor, Weights};
    use crate::graph::layer::Op;
    use crate::graph::zoo;
    use crate::overlay::layer_sim::simulate_layer;
    use crate::util::rng::Rng;

    let name = args.get_or("model", "mini-inception");
    let Some(cnn) = zoo::by_name(name) else {
        eprintln!("unknown model '{name}'");
        return 1;
    };
    if cnn.total_macs() > 50_000_000 {
        eprintln!(
            "'{name}' is too large for functional cycle simulation; use `dse` (analytic) instead"
        );
        return 1;
    }
    // small array so per-layer GEMMs stay quick
    let p1 = args.get_usize("p1", 16);
    let p2 = args.get_usize("p2", 16);
    let compiler = Compiler::new();
    let g = compiler.build_graph(&cnn, p1, p2);
    let mapping = g.solve(&cnn);
    let mut rng = Rng::new(7);
    let mut t = Table::new(
        &format!("{name} — overlay simulation on {p1}×{p2} array"),
        &["layer", "algo", "dataflow", "CU cycles", "aux cycles", "model cycles", "sim μ"],
    );
    let mut ok = true;
    for l in &mapping.layers {
        let node = cnn.node(l.node);
        let Op::Conv(spec) = &node.op else { continue };
        let input = Tensor::random(spec.c_in, spec.h1, spec.h2, &mut rng);
        let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, &mut rng);
        let sim = simulate_layer(&input, &w, spec, l.cost.algo, l.cost.dataflow, p1, p2);
        let model_cycles = l.cost.cycles;
        // Eq. 10–12 model the Computing-Unit GEMM cycles (+ LT for
        // Winograd); the simulator separately exposes the aux-module
        // cycles (Pad-and-Accumulate tail) that the paper's pipelining
        // assumption hides for realistic layer/array sizes.
        let close =
            (sim.cu_cycles as f64 - model_cycles as f64).abs() / (model_cycles as f64) < 0.25;
        ok &= close;
        t.row(vec![
            l.name.clone(),
            l.cost.algo.name(),
            l.cost.dataflow.name().into(),
            sim.cu_cycles.to_string(),
            sim.aux_cycles.to_string(),
            model_cycles.to_string(),
            format!("{:.3}", sim.utilization),
        ]);
    }
    println!("{}", t.render());
    if ok {
        println!("simulated CU cycles agree with the Eq. 10-12 model (±25%)");
        0
    } else {
        println!("WARNING: simulation diverged from the model on some layers");
        1
    }
}

/// `dynamap infer --artifacts artifacts --policy opt --n 20
/// [--plan-cache plans]` — run the end-to-end PJRT serving session:
/// golden validation + latency bench. With `--plan-cache`, the DSE plan
/// is persisted and reused across invocations.
pub fn infer(args: &Args) -> i32 {
    let dir = args.get_or("artifacts", "artifacts");
    let n = args.get_usize("n", 20);
    let mut builder = Session::builder(dir);
    match args.get_or("policy", "opt") {
        "opt" | "optimal" => {}
        "im2col" => builder = builder.policy(Policy::Im2colOnly),
        "kn2row" => builder = builder.policy(Policy::Kn2rowApplied),
        "wino" | "winograd" => builder = builder.policy(Policy::WinoApplied),
        "greedy" => builder = builder.policy(Policy::Greedy),
        other => {
            eprintln!("unknown policy '{other}'");
            return 2;
        }
    }
    if let Some(cache) = args.get("plan-cache") {
        builder = builder.plan_cache(cache);
    }
    let mut session = match builder.build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("session init failed: {e} (run `make artifacts` first)");
            return 1;
        }
    };
    println!(
        "session ready: model={}, {} executables compiled, plan {}, mapping: {:?}",
        session.model(),
        session.loaded_executables(),
        if session.plan_from_cache() { "loaded from cache" } else { "freshly compiled" },
        session.algo_map()
    );
    match session.validate_golden() {
        Ok(err) => {
            println!("golden validation: max |Δ| = {err:.2e}");
            if err > 1e-3 {
                eprintln!("FAIL: golden mismatch");
                return 1;
            }
        }
        Err(e) => {
            eprintln!("golden validation failed: {e}");
            return 1;
        }
    }
    match session.bench(n) {
        Ok(stats) => {
            println!("latency ({n} runs): {}", stats.summary());
            0
        }
        Err(e) => {
            eprintln!("bench failed: {e}");
            1
        }
    }
}
