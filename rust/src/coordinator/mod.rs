//! L3 coordinator: the end-to-end inference layer.
//!
//! Chains per-layer PJRT executables according to the DSE-chosen
//! algorithm mapping — the functional embodiment of dynamic algorithm
//! mapping: each conv layer runs the AOT artifact of *its* algorithm,
//! while pooling and concat execute natively in Rust between them.
//! Python never runs on this path.
//!
//! The serving implementation lives in [`crate::api::Session`];
//! [`InferenceEngine`]/[`EnginePolicy`] remain as deprecated shims for
//! one release. [`metrics::LatencyStats`] is shared with the new API.

pub mod engine;
pub mod metrics;
pub mod cli;

pub use engine::InferMetrics;
#[allow(deprecated)]
pub use engine::{EnginePolicy, InferenceEngine};
pub use metrics::LatencyStats;
