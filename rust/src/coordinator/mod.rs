//! L3 coordinator: latency accounting + the simulation/inference CLI.
//!
//! The end-to-end serving implementation lives in
//! [`crate::api::Session`] — build one with `Session::builder`, using
//! `.policy(..)` for fixed-baseline mappings or `.algo_map(..)` for an
//! explicit per-layer map. [`metrics::LatencyStats`] is shared with
//! the staged API.

pub mod metrics;
pub mod cli;

pub use metrics::LatencyStats;
