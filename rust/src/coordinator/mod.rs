//! L3 coordinator: latency accounting + the simulation/inference CLI.
//!
//! The end-to-end serving implementation lives in
//! [`crate::api::Session`] (the 0.1 `InferenceEngine`/`EnginePolicy`
//! shims have been removed; `Session::builder` with `.policy(..)` /
//! `.algo_map(..)` covers their call shapes).
//! [`metrics::LatencyStats`] is shared with the staged API.

pub mod metrics;
pub mod cli;

pub use metrics::LatencyStats;
