//! Latency accounting for the inference engine.

/// Aggregated latency statistics over repeated inferences.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    pub samples_us: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> LatencyStats {
        LatencyStats { samples_us: Vec::new() }
    }

    pub fn push(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    fn sorted(&self) -> Vec<f64> {
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let v = self.sorted();
        if v.is_empty() {
            return 0.0;
        }
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.sorted().first().copied().unwrap_or(0.0)
    }

    pub fn max(&self) -> f64 {
        self.sorted().last().copied().unwrap_or(0.0)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs min={:.1}µs max={:.1}µs",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.min(),
            self.max()
        )
    }
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = LatencyStats::new();
        for v in [10.0, 20.0, 30.0, 40.0, 50.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 30.0);
        assert_eq!(s.percentile(50.0), 30.0);
        assert_eq!(s.min(), 10.0);
        assert_eq!(s.max(), 50.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }
}
