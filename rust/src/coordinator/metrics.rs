//! Latency accounting for the serving layers ([`crate::api::Session`]
//! per-session aggregates and the per-model end-to-end histograms in
//! [`crate::serve::ServerMetrics`]).

/// Aggregated latency statistics over repeated inferences.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    /// Raw samples, microseconds, in arrival order.
    pub samples_us: Vec<f64>,
}

impl LatencyStats {
    /// Empty statistics.
    pub fn new() -> LatencyStats {
        LatencyStats { samples_us: Vec::new() }
    }

    /// Record one sample (microseconds).
    pub fn push(&mut self, us: f64) {
        self.samples_us.push(us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    /// Arithmetic mean; `0.0` when empty.
    pub fn mean(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<f64>() / self.samples_us.len() as f64
    }

    fn sorted(&self) -> Vec<f64> {
        let mut v = self.samples_us.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v
    }

    /// Nearest-rank percentile: `p` in `[0, 100]` maps onto the sorted
    /// sample index `round(p/100 · (n-1))`. Degenerate inputs are
    /// total: an empty set yields `0.0`, a single sample is every
    /// percentile of itself, and `p` outside `[0, 100]` clamps to
    /// min/max.
    pub fn percentile(&self, p: f64) -> f64 {
        self.percentiles(&[p])[0]
    }

    /// Several nearest-rank percentiles resolved against a single
    /// sorted copy of the samples — cheaper than repeated
    /// [`LatencyStats::percentile`] calls for p50/p95/p99 reporting.
    pub fn percentiles(&self, ps: &[f64]) -> Vec<f64> {
        let v = self.sorted();
        ps.iter()
            .map(|&p| {
                if v.is_empty() {
                    return 0.0;
                }
                let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
                v[idx.min(v.len() - 1)]
            })
            .collect()
    }

    /// Smallest sample; `0.0` when empty.
    pub fn min(&self) -> f64 {
        self.sorted().first().copied().unwrap_or(0.0)
    }

    /// Largest sample; `0.0` when empty.
    pub fn max(&self) -> f64 {
        self.sorted().last().copied().unwrap_or(0.0)
    }

    /// One-line `n/mean/p50/p95/min/max` summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.1}µs p50={:.1}µs p95={:.1}µs min={:.1}µs max={:.1}µs",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.min(),
            self.max()
        )
    }
}

impl Default for LatencyStats {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let mut s = LatencyStats::new();
        for v in [10.0, 20.0, 30.0, 40.0, 50.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 30.0);
        assert_eq!(s.percentile(50.0), 30.0);
        assert_eq!(s.min(), 10.0);
        assert_eq!(s.max(), 50.0);
        assert_eq!(s.count(), 5);
    }

    #[test]
    fn empty_is_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.count(), 0);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        let mut s = LatencyStats::new();
        s.push(42.0);
        for p in [0.0, 1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 42.0, "p={p}");
        }
        assert_eq!(s.mean(), 42.0);
    }

    #[test]
    fn percentile_bounds_hit_min_and_max() {
        let mut s = LatencyStats::new();
        // unsorted on purpose: percentile must sort internally
        for v in [30.0, 10.0, 50.0, 20.0, 40.0] {
            s.push(v);
        }
        assert_eq!(s.percentile(0.0), 10.0, "p=0 is the minimum");
        assert_eq!(s.percentile(100.0), 50.0, "p=100 is the maximum");
        // out-of-range p clamps instead of panicking
        assert_eq!(s.percentile(-5.0), 10.0);
        assert_eq!(s.percentile(250.0), 50.0);
        // tail percentiles are monotone
        assert!(s.percentile(95.0) <= s.percentile(99.0));
        assert!(s.percentile(99.0) <= s.percentile(100.0));
        // the single-sort batch form agrees with one-at-a-time calls
        assert_eq!(
            s.percentiles(&[0.0, 50.0, 100.0]),
            vec![s.percentile(0.0), s.percentile(50.0), s.percentile(100.0)]
        );
        assert_eq!(LatencyStats::new().percentiles(&[50.0, 99.0]), vec![0.0, 0.0]);
    }
}
