//! The inference engine: DSE plan → per-layer PJRT executables →
//! topological execution with native pooling/concat.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use super::metrics::LatencyStats;
use crate::algos::tensor::Tensor;
use crate::cost::conv::Algo;
use crate::cost::graph_build::Policy;
use crate::dse::{Dse, DseConfig};
use crate::graph::layer::Op;
use crate::graph::{zoo, Cnn};
use crate::overlay::pooling;
use crate::runtime::{Manifest, PjrtRuntime, TensorBuf};

/// How the engine picks each layer's algorithm.
#[derive(Debug, Clone)]
pub enum EnginePolicy {
    /// DYNAMAP's optimal PBQP mapping (clamped to AOT'd algorithms).
    Optimal,
    /// A fixed baseline policy (bl3/bl4/bl5/greedy).
    Baseline(Policy),
    /// Explicit per-layer map (layer name → algorithm name).
    Custom(BTreeMap<String, String>),
}

/// Per-inference metrics.
#[derive(Debug, Clone)]
pub struct InferMetrics {
    pub total_us: f64,
    /// (layer name, algorithm, microseconds) per conv layer.
    pub per_layer_us: Vec<(String, String, f64)>,
}

/// The end-to-end engine.
pub struct InferenceEngine {
    pub manifest: Manifest,
    pub cnn: Cnn,
    /// conv layer name → chosen algorithm name.
    pub algo_map: BTreeMap<String, String>,
    runtime: PjrtRuntime,
    weights: BTreeMap<String, TensorBuf>,
}

impl InferenceEngine {
    /// Build the engine: load the manifest, run the DSE flow to choose
    /// the algorithm mapping, pre-compile every chosen executable and
    /// pre-load weights.
    pub fn new(artifacts_dir: &str, policy: EnginePolicy) -> Result<InferenceEngine, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        if manifest.model != "mini-inception" {
            return Err(format!("unsupported artifact model '{}'", manifest.model));
        }
        let cnn = zoo::mini_inception();

        // choose algorithms
        let algo_map: BTreeMap<String, String> = match policy {
            EnginePolicy::Custom(m) => m,
            EnginePolicy::Optimal | EnginePolicy::Baseline(_) => {
                let dse = Dse::new(DseConfig::alveo_u200());
                let plan = match policy {
                    EnginePolicy::Optimal => dse.run(&cnn)?,
                    EnginePolicy::Baseline(p) => dse.run_policy(&cnn, p)?,
                    EnginePolicy::Custom(_) => unreachable!(),
                };
                plan.mapping
                    .layers
                    .iter()
                    .map(|l| {
                        let a = match l.cost.algo {
                            Algo::Im2col => "im2col",
                            Algo::Kn2row => "kn2row",
                            Algo::Winograd { .. } | Algo::WinogradStrided { .. } => "winograd",
                        };
                        (l.name.clone(), a.to_string())
                    })
                    .collect()
            }
        };

        // clamp to AOT'd algorithms & pre-compile
        let mut runtime = PjrtRuntime::cpu()?;
        let mut clamped = BTreeMap::new();
        let mut weights = BTreeMap::new();
        for layer in &manifest.layers {
            let want = algo_map.get(&layer.name).map(|s| s.as_str()).unwrap_or("im2col");
            let algo = if layer.algos.contains_key(want) { want } else { "im2col" };
            let art = layer
                .algos
                .get(algo)
                .ok_or_else(|| format!("{}: no artifact for {algo}", layer.name))?;
            runtime.load(&manifest.dir.join(art))?;
            clamped.insert(layer.name.clone(), algo.to_string());
            let w = manifest.weights(layer)?;
            weights.insert(
                layer.name.clone(),
                TensorBuf::new(vec![layer.c_out, layer.c_in, layer.k1, layer.k2], w),
            );
        }
        Ok(InferenceEngine { manifest, cnn, algo_map: clamped, runtime, weights })
    }

    fn artifact_path(&self, layer: &str) -> PathBuf {
        let a = &self.algo_map[layer];
        let file = &self.manifest.layer(layer).unwrap().algos[a];
        self.manifest.dir.join(file)
    }

    /// Run one inference. Input is `(C, H, W)` flattened f32.
    pub fn infer(&mut self, input: &TensorBuf) -> Result<(TensorBuf, InferMetrics), String> {
        let t_total = Instant::now();
        let mut per_layer = Vec::new();
        let mut values: BTreeMap<usize, TensorBuf> = BTreeMap::new();
        let order = self.cnn.topo_order();
        let mut final_out = None;
        for id in order {
            let node = self.cnn.node(id).clone();
            let preds = self.cnn.predecessors(id);
            let out = match &node.op {
                Op::Input { c, h1, h2 } => {
                    if input.len() != c * h1 * h2 {
                        return Err(format!(
                            "input len {} != expected {}",
                            input.len(),
                            c * h1 * h2
                        ));
                    }
                    TensorBuf::new(vec![*c, *h1, *h2], input.data.clone())
                }
                Op::Conv(spec) => {
                    let x = &values[&preds[0]];
                    let w = self.weights[&node.name].clone();
                    let path = self.artifact_path(&node.name);
                    let t0 = Instant::now();
                    let out = self.runtime.execute(
                        &path,
                        &[x, &w],
                        vec![spec.c_out, spec.o1(), spec.o2()],
                    )?;
                    per_layer.push((
                        node.name.clone(),
                        self.algo_map[&node.name].clone(),
                        t0.elapsed().as_secs_f64() * 1e6,
                    ));
                    out
                }
                Op::Pool(p) => {
                    let x = &values[&preds[0]];
                    let t = Tensor { c: p.c, h: p.h1, w: p.h2, data: x.data.clone() };
                    let out = pooling::reference(&t, p);
                    TensorBuf::new(vec![out.c, out.h, out.w], out.data)
                }
                Op::Concat { c_out, h1, h2 } => {
                    let mut data = Vec::with_capacity(c_out * h1 * h2);
                    for &p in &preds {
                        data.extend_from_slice(&values[&p].data);
                    }
                    TensorBuf::new(vec![*c_out, *h1, *h2], data)
                }
                Op::Add { c, h1, h2 } => {
                    let a = &values[&preds[0]];
                    let b = &values[&preds[1]];
                    let data = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
                    TensorBuf::new(vec![*c, *h1, *h2], data)
                }
                Op::Fc { .. } => {
                    return Err("FC layers are not part of the artifact set".into())
                }
                Op::Output => {
                    final_out = Some(values[&preds[0]].clone());
                    continue;
                }
            };
            values.insert(id, out);
        }
        let out = final_out.ok_or("no output node reached")?;
        Ok((
            out,
            InferMetrics { total_us: t_total.elapsed().as_secs_f64() * 1e6, per_layer_us: per_layer },
        ))
    }

    /// Validate against the Python-side golden pair; returns the max
    /// absolute error.
    pub fn validate_golden(&mut self) -> Result<f32, String> {
        let (gi, go) = self.manifest.golden()?;
        let (c, h1, h2) = self.manifest.input;
        let input = TensorBuf::new(vec![c, h1, h2], gi);
        let (out, _) = self.infer(&input)?;
        if out.data.len() != go.len() {
            return Err(format!("golden length {} != output {}", go.len(), out.data.len()));
        }
        let mut max_err = 0.0f32;
        for (a, b) in out.data.iter().zip(&go) {
            max_err = max_err.max((a - b).abs());
        }
        Ok(max_err)
    }

    /// Latency benchmark: `n` sequential inferences on the golden input
    /// (first call warms the executable cache).
    pub fn bench(&mut self, n: usize) -> Result<LatencyStats, String> {
        let (gi, _) = self.manifest.golden()?;
        let (c, h1, h2) = self.manifest.input;
        let input = TensorBuf::new(vec![c, h1, h2], gi);
        let mut stats = LatencyStats::new();
        self.infer(&input)?; // warm-up
        for _ in 0..n {
            let (_, m) = self.infer(&input)?;
            stats.push(m.total_us);
        }
        Ok(stats)
    }

    /// Executables currently compiled.
    pub fn loaded_executables(&self) -> usize {
        self.runtime.loaded_count()
    }
}
