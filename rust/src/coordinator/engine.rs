//! Deprecated shim over [`crate::api::Session`].
//!
//! The inference engine of the first release constructed the whole
//! pipeline — manifest load, DSE, executable compilation — inside one
//! monolithic constructor, re-running the DSE on every instantiation
//! and only accepting the `mini-inception` manifest. The staged
//! replacement lives in [`crate::api`]: build a
//! [`Session`](crate::api::Session) (optionally from a cached
//! [`PlanArtifact`](crate::api::PlanArtifact)) and serve `infer` /
//! `infer_batch` from it.

use std::collections::BTreeMap;

use crate::api::{DynamapError, Session};
use crate::coordinator::metrics::LatencyStats;
use crate::cost::graph_build::Policy;
use crate::runtime::{Manifest, TensorBuf};

pub use crate::api::session::InferMetrics;

/// How the engine picks each layer's algorithm.
#[deprecated(
    since = "0.2.0",
    note = "use dynamap::api::Session::builder with .policy(..) or .algo_map(..)"
)]
#[derive(Debug, Clone)]
pub enum EnginePolicy {
    /// DYNAMAP's optimal PBQP mapping (clamped to AOT'd algorithms).
    Optimal,
    /// A fixed baseline policy (bl3/bl4/bl5/greedy).
    Baseline(Policy),
    /// Explicit per-layer map (layer name → algorithm name).
    Custom(BTreeMap<String, String>),
}

/// The end-to-end engine, now a thin wrapper around
/// [`crate::api::Session`].
#[deprecated(since = "0.2.0", note = "use dynamap::api::Session")]
pub struct InferenceEngine {
    session: Session,
}

#[allow(deprecated)]
impl InferenceEngine {
    /// Build the engine: resolves the model from the manifest, runs (or
    /// loads) the plan and pre-compiles every chosen executable.
    pub fn new(
        artifacts_dir: &str,
        policy: EnginePolicy,
    ) -> Result<InferenceEngine, DynamapError> {
        let mut builder = Session::builder(artifacts_dir);
        builder = match policy {
            EnginePolicy::Optimal => builder,
            EnginePolicy::Baseline(p) => builder.policy(p),
            EnginePolicy::Custom(m) => builder.algo_map(m),
        };
        Ok(InferenceEngine { session: builder.build()? })
    }

    /// The wrapped session.
    pub fn session(&mut self) -> &mut Session {
        &mut self.session
    }

    pub fn manifest(&self) -> &Manifest {
        self.session.manifest()
    }

    /// conv layer name → chosen algorithm name.
    pub fn algo_map(&self) -> &BTreeMap<String, String> {
        self.session.algo_map()
    }

    /// Run one inference. Input is `(C, H, W)` flattened f32.
    pub fn infer(
        &mut self,
        input: &TensorBuf,
    ) -> Result<(TensorBuf, InferMetrics), DynamapError> {
        self.session.infer(input)
    }

    /// Validate against the Python-side golden pair; returns the max
    /// absolute error.
    pub fn validate_golden(&mut self) -> Result<f32, DynamapError> {
        self.session.validate_golden()
    }

    /// Latency benchmark: `n` sequential inferences on the golden input.
    pub fn bench(&mut self, n: usize) -> Result<LatencyStats, DynamapError> {
        self.session.bench(n)
    }

    /// Executables currently compiled.
    pub fn loaded_executables(&self) -> usize {
        self.session.loaded_executables()
    }
}
