//! Quantized int8 GEMM beside the f32 kernel: packed `Wᵀ` panels of
//! i8-range values, i32 accumulation, f32 requantization.
//!
//! The layout mirrors [`super::gemm`] — `Wᵀ` packed once per layer so
//! every output element is one dot product over two contiguous slices —
//! but the operands are quantized to the symmetric int8 grid and
//! carried in **i16 lanes**: the host analogue of FPGA DSP packing.
//! Two things make this kernel faster than the f32 one on the same
//! shapes:
//!
//! * integer addition is associative, so the compiler is free to
//!   vectorize the i32 reduction (the f32 kernel must preserve
//!   ascending-`k` order to stay bit-identical to the naive reference,
//!   which forbids reassociation);
//! * i16 operands halve the memory traffic per multiply.
//!
//! Numerical contract: i32 sums are exact (no rounding, no order
//! sensitivity — the reduction depth is hard-asserted below the i32
//! overflow bound), so the kernel's
//! output is **bit-identical** to the scalar reference
//! [`crate::quant::qgemm_requant_ref`] for any summation order; the
//! property tests below assert exactly that on ragged shapes.

use crate::algos::tensor::Mat;
use crate::quant::scale::{max_abs, quantize_slice, quantize_value, symmetric_scale};

/// Column-panel group size, matching the f32 kernel's blocking.
const NC: usize = 128;

/// Largest reduction depth the i32 accumulator provably cannot
/// overflow at: `b · 127 · 127 < i32::MAX`.
const MAX_DEPTH: usize = (i32::MAX / (127 * 127)) as usize;

/// Quantized `Wᵀ` panels: per-output-channel (= per-column of `W`)
/// symmetric scales, values on the int8 grid in i16 lanes.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedWtI8 {
    /// Depth (rows of `W`, the reduction dimension).
    pub b: usize,
    /// Columns of `W` (= output channels = panel count).
    pub c: usize,
    data: Vec<i16>,
    scales: Vec<f32>,
}

impl PackedWtI8 {
    /// Quantize and pack a `b × c` matrix `W`, one symmetric scale per
    /// output column (paid once per layer at prepare time). The
    /// transpose is the one shared packing path, so the scale rule can
    /// never diverge between the two entry points.
    pub fn quantize(w: &Mat) -> PackedWtI8 {
        PackedWtI8::quantize_wt(&w.transposed())
    }

    /// Quantize a matrix that is *already* `Wᵀ` (`c × b` row-major,
    /// e.g. the im2col weight matrix or a kn2row per-tap unit matrix):
    /// each row is one output channel and becomes one scaled panel.
    pub fn quantize_wt(wt: &Mat) -> PackedWtI8 {
        let (c, b) = (wt.rows, wt.cols);
        let mut data = vec![0i16; b * c];
        let mut scales = vec![0f32; c];
        for j in 0..c {
            let row = &wt.data[j * b..(j + 1) * b];
            let s = symmetric_scale(max_abs(row));
            scales[j] = s;
            for (k, &v) in row.iter().enumerate() {
                data[j * b + k] = quantize_value(v, s);
            }
        }
        PackedWtI8 { b, c, data, scales }
    }

    /// Quantized column `j` of `W` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[i16] {
        &self.data[j * self.b..(j + 1) * self.b]
    }

    /// Dequantization scale of output column `j`.
    #[inline]
    pub fn scale(&self, j: usize) -> f32 {
        self.scales[j]
    }
}

/// A per-tensor-quantized activation matrix: i8-range values in i16
/// lanes plus the one shared scale. Built once per GEMM call (im2col)
/// or once per *layer invocation* and reused across taps (kn2row).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantMat {
    /// Rows of the original matrix.
    pub rows: usize,
    /// Columns of the original matrix (the reduction dimension).
    pub cols: usize,
    /// Shared symmetric scale.
    pub scale: f32,
    data: Vec<i16>,
}

impl QuantMat {
    /// Quantize `x` with a per-tensor symmetric scale derived from its
    /// own max magnitude (dynamic quantization).
    pub fn quantize(x: &Mat) -> QuantMat {
        QuantMat::quantize_scaled(x, symmetric_scale(max_abs(&x.data)))
    }

    /// Quantize `x` with an explicit (calibrated) scale.
    pub fn quantize_scaled(x: &Mat, scale: f32) -> QuantMat {
        QuantMat {
            rows: x.rows,
            cols: x.cols,
            scale,
            data: quantize_slice(&x.data, scale),
        }
    }

    /// Quantized row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[i16] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }
}

/// `X (a×b) · W (b×c)` on the int8 grid with f32 requantization:
/// `out[i][j] = (Σ_k xq[i][k]·wq[k][j]) · (x.scale · w.scale(j))`.
/// Panics on a depth mismatch.
pub fn qgemm(x: &QuantMat, w: &PackedWtI8) -> Mat {
    assert_eq!(x.cols, w.b, "kernels::qgemm depth mismatch");
    // hard assert: past this depth the i32 accumulator could wrap and
    // release builds would return silently wrong activations. One
    // comparison per GEMM call — not per element — so it costs nothing
    // on the hot path.
    assert!(w.b <= MAX_DEPTH, "i32 accumulator would overflow at depth {}", w.b);
    let (a, c) = (x.rows, w.c);
    let mut out = Mat::zeros(a, c);
    for jc in (0..c).step_by(NC) {
        let jc_end = (jc + NC).min(c);
        for i in 0..a {
            let x_row = x.row(i);
            let out_row = &mut out.data[i * c..(i + 1) * c];
            let mut j = jc;
            // 4 independent panels per iteration, exactly like the f32
            // microkernel; each i32 reduction is free to vectorize
            while j + 4 <= jc_end {
                let w0 = w.col(j);
                let w1 = w.col(j + 1);
                let w2 = w.col(j + 2);
                let w3 = w.col(j + 3);
                let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
                for k in 0..x_row.len() {
                    let xv = x_row[k] as i32;
                    s0 += xv * w0[k] as i32;
                    s1 += xv * w1[k] as i32;
                    s2 += xv * w2[k] as i32;
                    s3 += xv * w3[k] as i32;
                }
                out_row[j] = s0 as f32 * (x.scale * w.scale(j));
                out_row[j + 1] = s1 as f32 * (x.scale * w.scale(j + 1));
                out_row[j + 2] = s2 as f32 * (x.scale * w.scale(j + 2));
                out_row[j + 3] = s3 as f32 * (x.scale * w.scale(j + 3));
                j += 4;
            }
            while j < jc_end {
                let wc = w.col(j);
                let mut s: i32 = 0;
                for k in 0..x_row.len() {
                    s += x_row[k] as i32 * wc[k] as i32;
                }
                out_row[j] = s as f32 * (x.scale * w.scale(j));
                j += 1;
            }
        }
    }
    out
}

/// Convenience wrapper quantizing both operands per call (dynamic
/// activation scale) — the one-shot form the bench and tests use. In a
/// serving loop prefer a prepared [`PackedWtI8`] and, for multi-call
/// algorithms, a shared [`QuantMat`].
pub fn qgemm_xw(x: &Mat, w: &Mat) -> Mat {
    assert_eq!(x.cols, w.rows, "kernels::qgemm_xw dims");
    qgemm(&QuantMat::quantize(x), &PackedWtI8::quantize(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::scale::qgemm_requant_ref;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn matches_scalar_reference_bitwise_random_shapes() {
        // ragged shapes not divisible by the microkernel width or the
        // panel block, plus degenerate 1-dims — the vectorizable i32
        // reduction must be bit-identical to the ascending-k scalar ref
        check("qgemm_vs_scalar_ref", 96, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 40), r.range(1, 40), r.range(1, 300));
            let x = Mat::from_fn(a, b, |_, _| r.f32_range(-2.0, 2.0));
            let w = Mat::from_fn(b, c, |_, _| r.f32_range(-1.0, 1.0));
            let fast = qgemm_xw(&x, &w);
            let reference = qgemm_requant_ref(&x, &w);
            if fast.data != reference.data {
                return Err(format!("bitwise mismatch for ({a},{b},{c})"));
            }
            Ok(())
        });
    }

    #[test]
    fn exact_on_grid_data() {
        // integer data whose max magnitude is exactly on the grid:
        // scale 1 on both sides, so the quantized GEMM equals the f32
        // matmul bitwise
        check("qgemm_grid_exact", 48, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 24), r.range(1, 24), r.range(1, 24));
            let mut x = Mat::from_fn(a, b, |_, _| r.i8_small() as f32);
            let mut w = Mat::from_fn(b, c, |_, _| r.i8_small() as f32);
            x.data[0] = 127.0;
            for j in 0..c {
                w.set(0, j, 127.0);
            }
            let q = qgemm_xw(&x, &w);
            let exact = x.matmul(&w);
            if q.data != exact.data {
                return Err(format!("on-grid mismatch for ({a},{b},{c})"));
            }
            Ok(())
        });
    }

    #[test]
    fn quantization_error_bounded_vs_f32() {
        check("qgemm_error_bound", 32, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 16), r.range(4, 64), r.range(1, 16));
            let x = Mat::from_fn(a, b, |_, _| r.f32_range(-1.0, 1.0));
            let w = Mat::from_fn(b, c, |_, _| r.f32_range(-0.5, 0.5));
            let q = qgemm_xw(&x, &w);
            let f = x.matmul(&w);
            let fmax = max_abs(&f.data).max(1e-6);
            for (i, (qa, fa)) in q.data.iter().zip(&f.data).enumerate() {
                if (qa - fa).abs() > 0.05 * fmax {
                    return Err(format!(
                        "({a},{b},{c}) elem {i}: |{qa} - {fa}| > 5% of {fmax}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn per_channel_scales_follow_columns() {
        // column j holds values up to 0.6·(j+1): scales must grow with j
        let w = Mat::from_fn(6, 3, |i, j| (i + 1) as f32 * 0.1 * (j + 1) as f32);
        let p = PackedWtI8::quantize(&w);
        assert!(p.scale(0) < p.scale(1) && p.scale(1) < p.scale(2));
        assert!((p.scale(2) / p.scale(0) - 3.0).abs() < 1e-6, "3x column, 3x scale");
        // quantize_wt on the transpose is the identical packing
        assert_eq!(PackedWtI8::quantize_wt(&w.transposed()), p);
    }

    #[test]
    fn static_scale_is_honoured() {
        let x = Mat { rows: 1, cols: 2, data: vec![0.5, -0.25] };
        let q = QuantMat::quantize_scaled(&x, 0.01);
        assert_eq!(q.scale, 0.01);
        assert_eq!(q.row(0), &[50, -25]);
        // dynamic picks the max-abs-derived scale instead
        let d = QuantMat::quantize(&x);
        assert_eq!(d.scale, symmetric_scale(0.5));
        assert_eq!(d.row(0), &[127, -64], "0.5 maps to the grid edge");
    }

    #[test]
    #[should_panic(expected = "depth mismatch")]
    fn depth_mismatch_panics() {
        let x = QuantMat::quantize(&Mat::zeros(2, 3));
        let w = PackedWtI8::quantize(&Mat::zeros(4, 2));
        qgemm(&x, &w);
    }
}
