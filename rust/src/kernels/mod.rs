//! Fast host-side kernel layer: functional compute decoupled from cycle
//! accounting.
//!
//! The overlay simulator ([`crate::overlay`]) answers two questions that
//! used to be entangled in one pass: *what is the output* and *what does
//! it cost on the array*. This module owns the first question — a
//! cache-blocked, transpose-free [`gemm`] over packed `Wᵀ` panels
//! ([`PackedWt`]) and per-layer pre-lowered weights
//! ([`PreparedWeights`]: im2col weight matrix, kn2row per-tap unit
//! matrices, Winograd `G g Gᵀ` kernels) built once at plan time — while
//! the cost question is answered closed-form by [`crate::cost::gemm`]
//! (Eq. 9–14). The split makes the serving hot path pure compute and is
//! cross-checked in two directions: kernel outputs are bit-identical to
//! the naive references in [`crate::algos`], and the analytic cycle
//! stats are asserted equal to the old loop-derived schedule walk
//! (`SystolicSim::loop_stats`) in debug builds and tests.
//!
//! Beside the f32 path sits the quantized int8 kernel layer
//! ([`qgemm`]): packed `Wᵀ` panels on the symmetric int8 grid with
//! per-output-channel scales, i32 accumulation and f32 requantization,
//! property-tested bit-identical to the scalar reference in
//! [`crate::quant`]. [`PreparedWeights`] carries quantized prepared
//! forms for im2col and kn2row; Winograd stays f32 (its transform-space
//! arithmetic amplifies quantization error), and the DSE knows it.
//!
//! On top of the packed f32 path sits the microkernel tier: a one-time
//! CPU capability probe and per-shape [`KernelSelector`] ([`select`])
//! feeding explicit-SIMD microkernels with double-buffered panel
//! packing ([`simd`]) — still bit-identical to [`Mat::matmul`] (the
//! kernels vectorize across output *columns*, so every element keeps
//! its ascending-`k` scalar accumulation order). The f32 prepared conv
//! paths route their GEMMs through [`simd::gemm`];
//! [`KernelSelector::measure`] exports the host's measured per-kernel
//! throughput to the cost model
//! ([`crate::cost::device::KernelThroughput`]) so the DSE prices what
//! the host actually runs.
//!
//! [`Mat::matmul`]: crate::algos::tensor::Mat::matmul
#![deny(clippy::correctness, clippy::suspicious)]
#![warn(missing_docs)]

pub mod gemm;
pub mod prepared;
pub mod qgemm;
pub mod select;
pub mod simd;

pub use gemm::{gemm, gemm_xw, PackedWt};
pub use prepared::{PreparedKernel, PreparedWeights};
pub use qgemm::{qgemm, qgemm_xw, PackedWtI8, QuantMat};
pub use select::{cpu_caps, CpuCaps, KernelChoice, KernelKind, KernelSelector};
