//! Cache-blocked, transpose-free GEMM over pre-packed `Wᵀ` panels.
//!
//! The overlay simulator's old hot path re-transposed `W` on every call
//! and walked per-PE scalar loops whose only purpose was cycle tallying.
//! This kernel separates the concerns: it computes `X (a×b) · W (b×c)`
//! as fast as the host allows, reading `W` through a [`PackedWt`] whose
//! rows are the *columns* of `W` — so every output element is one dot
//! product over two contiguous slices, with no per-call allocation
//! beyond the output.
//!
//! Numerical contract: each output element accumulates in ascending-`k`
//! order, exactly like [`Mat::matmul`], so results are **bit-identical**
//! to the naive reference (asserted by the property tests below). The
//! microkernel gains its speed from instruction-level parallelism
//! *across output columns* (4 independent accumulators), never from
//! reassociating a single sum.

use crate::algos::tensor::Mat;

/// Column-panel group kept hot across the row loop (`NC · b` floats per
/// group — sized so a group of panels stays L2-resident for typical
/// layer shapes).
const NC: usize = 128;

/// `Wᵀ` stored row-major: `data[j·b .. (j+1)·b]` is column `j` of the
/// original `b × c` matrix `W`. Pack once per layer (or take a matrix
/// that is already `c × b`, e.g. the im2col weight matrix) and reuse
/// across every GEMM call.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedWt {
    /// Depth (rows of `W`, i.e. the reduction dimension).
    pub b: usize,
    /// Columns of `W` (= panel count).
    pub c: usize,
    data: Vec<f32>,
}

impl PackedWt {
    /// Pack a `b × c` matrix `W` (one transpose, paid at prepare time).
    pub fn pack(w: &Mat) -> PackedWt {
        let (b, c) = (w.rows, w.cols);
        let mut data = vec![0.0f32; b * c];
        for j in 0..c {
            for k in 0..b {
                data[j * b + k] = w.data[k * c + j];
            }
        }
        PackedWt { b, c, data }
    }

    /// Adopt a matrix that is *already* `Wᵀ` (`c × b` row-major) without
    /// copying — e.g. `im2col::weight_matrix` or a kn2row per-tap unit
    /// matrix, which the algorithms naturally produce transposed.
    pub fn from_wt(wt: Mat) -> PackedWt {
        PackedWt { b: wt.cols, c: wt.rows, data: wt.data }
    }

    /// Column `j` of `W` as a contiguous slice.
    #[inline]
    pub fn col(&self, j: usize) -> &[f32] {
        &self.data[j * self.b..(j + 1) * self.b]
    }

    /// View as the `c × b` matrix `Wᵀ`.
    pub fn as_wt_mat(&self) -> Mat {
        Mat { rows: self.c, cols: self.b, data: self.data.clone() }
    }
}

/// One sequential dot product over two equal-length slices.
#[inline]
fn dot(x: &[f32], w: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), w.len());
    let mut s = 0.0f32;
    for k in 0..x.len() {
        s += x[k] * w[k];
    }
    s
}

/// `X (a×b) · W (b×c)` with `W` pre-packed. Panics on a depth mismatch.
pub fn gemm(x: &Mat, w: &PackedWt) -> Mat {
    assert_eq!(x.cols, w.b, "kernels::gemm depth mismatch");
    let (a, b, c) = (x.rows, x.cols, w.c);
    let mut out = Mat::zeros(a, c);
    // block over column panels so a group of NC panels is reused across
    // every row of X before the next group is streamed in
    for jc in (0..c).step_by(NC) {
        let jc_end = (jc + NC).min(c);
        for i in 0..a {
            let x_row = &x.data[i * b..(i + 1) * b];
            let out_row = &mut out.data[i * c..(i + 1) * c];
            let mut j = jc;
            // 4-wide microkernel: four independent accumulators share
            // each x load; every accumulator still sums in k order
            while j + 4 <= jc_end {
                let w0 = w.col(j);
                let w1 = w.col(j + 1);
                let w2 = w.col(j + 2);
                let w3 = w.col(j + 3);
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for k in 0..b {
                    let xv = x_row[k];
                    s0 += xv * w0[k];
                    s1 += xv * w1[k];
                    s2 += xv * w2[k];
                    s3 += xv * w3[k];
                }
                out_row[j] = s0;
                out_row[j + 1] = s1;
                out_row[j + 2] = s2;
                out_row[j + 3] = s3;
                j += 4;
            }
            while j < jc_end {
                out_row[j] = dot(x_row, w.col(j));
                j += 1;
            }
        }
    }
    out
}

/// Convenience wrapper packing `W` per call — for one-shot GEMMs where
/// no [`PackedWt`] is cached. Prefer [`gemm`] on a prepared operand in
/// any loop.
pub fn gemm_xw(x: &Mat, w: &Mat) -> Mat {
    assert_eq!(x.cols, w.rows, "kernels::gemm_xw dims");
    gemm(x, &PackedWt::pack(w))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_mat(r: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| r.i8_small() as f32)
    }

    fn random_mat_f32(r: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| r.f32_range(-1.0, 1.0))
    }

    #[test]
    fn pack_round_trips() {
        let mut r = Rng::new(1);
        let w = random_mat(&mut r, 7, 5);
        let p = PackedWt::pack(&w);
        assert_eq!((p.b, p.c), (7, 5));
        for j in 0..5 {
            for k in 0..7 {
                assert_eq!(p.col(j)[k], w.get(k, j));
            }
        }
        assert_eq!(p.as_wt_mat(), w.transposed());
    }

    #[test]
    fn from_wt_is_zero_copy_pack() {
        let mut r = Rng::new(2);
        let w = random_mat(&mut r, 9, 4);
        assert_eq!(PackedWt::from_wt(w.transposed()), PackedWt::pack(&w));
    }

    #[test]
    fn matches_naive_matmul_bitwise_random_shapes() {
        // includes ragged shapes not divisible by the microkernel width
        // or the NC panel block, plus degenerate 1-dims
        check("kernels_gemm_vs_matmul", 96, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 40), r.range(1, 40), r.range(1, 300));
            let x = random_mat_f32(r, a, b);
            let w = random_mat_f32(r, b, c);
            let fast = gemm_xw(&x, &w);
            let naive = x.matmul(&w);
            if fast.data != naive.data {
                return Err(format!("bitwise mismatch for ({a},{b},{c})"));
            }
            Ok(())
        });
    }

    #[test]
    fn exact_on_integer_data() {
        check("kernels_gemm_int_exact", 48, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 24), r.range(1, 24), r.range(1, 24));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            let p = PackedWt::pack(&w);
            let fast = gemm(&x, &p);
            let naive = x.matmul(&w);
            if fast != naive {
                return Err(format!("mismatch for ({a},{b},{c})"));
            }
            Ok(())
        });
    }

    #[test]
    fn identity_and_known_values() {
        let id = Mat::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        let m = Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(gemm_xw(&m, &id), m);
        let a = Mat { rows: 2, cols: 2, data: vec![1.0, 2.0, 3.0, 4.0] };
        let b = Mat { rows: 2, cols: 2, data: vec![1.0, 1.0, 1.0, 1.0] };
        assert_eq!(gemm_xw(&a, &b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    #[should_panic(expected = "depth mismatch")]
    fn depth_mismatch_panics() {
        let x = Mat::zeros(2, 3);
        let w = PackedWt::pack(&Mat::zeros(4, 2));
        gemm(&x, &w);
    }
}
