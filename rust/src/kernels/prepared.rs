//! Pre-lowered per-layer weights: the offline half of the fast conv
//! path.
//!
//! fpgaConvNet and f-CNNx both pre-lower weights into the on-device
//! layout in the offline toolflow; DYNAMAP's analogue is per-algorithm:
//! the im2col weight matrix, kn2row's per-tap unit matrices and the
//! Winograd-transformed kernels `G g Gᵀ` depend only on the layer's
//! weights and chosen algorithm — never on the request — so a serving
//! session builds a [`PreparedWeights`] once per layer at plan time and
//! the request path is pure compute on packed panels.

use super::gemm::PackedWt;
use super::qgemm::{qgemm, PackedWtI8, QuantMat};
// the f32 GEMMs run on the SIMD microkernel tier — bit-identical to
// `gemm::gemm` (and `Mat::matmul`), so swapping the entry point changes
// latency only, never a single output bit
use super::simd::gemm;
use crate::algos::tensor::{Mat, Tensor, Weights};
use crate::algos::{im2col, kn2row, winograd};
use crate::cost::conv::Algo;
use crate::graph::layer::ConvSpec;
use crate::quant::{ActQuant, Precision};

/// The algorithm-specific pre-lowered form.
#[derive(Debug, Clone)]
pub enum PreparedKernel {
    /// im2col: the `C_out × K1K2C_in` weight matrix — already `Wᵀ` of
    /// the `(O1O2 × K1K2C_in) · (K1K2C_in × C_out)` GEMM.
    Im2col {
        /// Packed `Wᵀ` panels.
        wt: PackedWt,
    },
    /// kn2row: one `C_out × C_in` unit matrix per kernel tap, in
    /// `(ky · K2 + kx)` order.
    Kn2row {
        /// Per-tap packed unit matrices.
        taps: Vec<PackedWt>,
    },
    /// Winograd F(m×m, r×r): per sub-kernel round (`gy · groups + gx`),
    /// the `(m+r−1)²` transformed point matrices `Uᵀ (C_out × C_in)`.
    Winograd {
        /// Output tile size `m`.
        m: usize,
        /// Kernel tile size `r`.
        r: usize,
        /// Sub-kernel rounds per axis (`⌈K/r⌉`).
        groups: usize,
        /// Per round, the `(m+r−1)²` packed point matrices.
        u: Vec<Vec<PackedWt>>,
    },
    /// Strided-Winograd extension: functional fallback through the
    /// polyphase decomposition keeps the raw weights.
    Direct {
        /// Raw layer weights.
        weights: Weights,
    },
    /// Quantized im2col: the same `Wᵀ` layout on the int8 grid with
    /// per-output-channel scales.
    QIm2col {
        /// Quantized packed `Wᵀ` panels.
        wt: PackedWtI8,
        /// Per-tensor activation-scale policy.
        act: ActQuant,
    },
    /// Quantized kn2row: per-tap unit matrices on the int8 grid. The
    /// tap-invariant input matrix is quantized **once** per request and
    /// shared by all `K1K2` tap GEMMs.
    QKn2row {
        /// Quantized per-tap unit matrices.
        taps: Vec<PackedWtI8>,
        /// Per-tensor activation-scale policy.
        act: ActQuant,
    },
}

/// Weights for one conv layer, lowered once for a chosen algorithm and
/// precision.
#[derive(Debug, Clone)]
pub struct PreparedWeights {
    /// The layer's convolution geometry.
    pub spec: ConvSpec,
    /// Algorithm the weights were lowered for.
    pub algo: Algo,
    /// The pre-lowered, packed (and possibly quantized) form.
    pub kernel: PreparedKernel,
}

impl PreparedWeights {
    /// Lower `weights` for `algo` at f32. This is the only place the
    /// per-layer transforms run; everything downstream reuses the
    /// packed panels.
    pub fn new(weights: &Weights, spec: &ConvSpec, algo: Algo) -> PreparedWeights {
        let kernel = match algo {
            Algo::Im2col => {
                PreparedKernel::Im2col { wt: PackedWt::from_wt(im2col::weight_matrix(weights)) }
            }
            Algo::Kn2row => {
                let mut taps = Vec::with_capacity(spec.k1 * spec.k2);
                for ky in 0..spec.k1 {
                    for kx in 0..spec.k2 {
                        taps.push(PackedWt::from_wt(kn2row::unit_weight_matrix(
                            weights, ky, kx,
                        )));
                    }
                }
                PreparedKernel::Kn2row { taps }
            }
            Algo::Winograd { m, r } => {
                assert_eq!((m, r), (2, 3), "kernel layer implements F(2×2, 3×3)");
                let a = m + r - 1;
                let groups = spec.k1.div_ceil(r);
                let mut u = Vec::with_capacity(groups * groups);
                for gy in 0..groups {
                    for gx in 0..groups {
                        let mut mats = vec![Mat::zeros(spec.c_out, spec.c_in); a * a];
                        for co in 0..spec.c_out {
                            for ci in 0..spec.c_in {
                                let k3 = Mat::from_fn(r, r, |y, x| {
                                    let ky = gy * r + y;
                                    let kx = gx * r + x;
                                    if ky < spec.k1 && kx < spec.k2 {
                                        weights.get(co, ci, ky, kx)
                                    } else {
                                        0.0
                                    }
                                });
                                let ut = winograd::transform_kernel(&k3);
                                for py in 0..a {
                                    for px in 0..a {
                                        mats[py * a + px].set(co, ci, ut.get(py, px));
                                    }
                                }
                            }
                        }
                        u.push(mats.into_iter().map(PackedWt::from_wt).collect());
                    }
                }
                PreparedKernel::Winograd { m, r, groups, u }
            }
            Algo::WinogradStrided { .. } => {
                PreparedKernel::Direct { weights: weights.clone() }
            }
        };
        PreparedWeights { spec: spec.clone(), algo, kernel }
    }

    /// Lower `weights` for `algo` at `precision`. Int8 lowering applies
    /// to im2col and kn2row; Winograd (and the strided extension)
    /// **clamps to f32** — its transform-space arithmetic amplifies
    /// quantization error, so the quantized grid is never offered there
    /// (the DSE encodes the same constraint). `act_scale` is the
    /// calibrated per-tensor activation scale for this layer
    /// ([`crate::quant::ActScales`]); when absent the layer quantizes
    /// dynamically from each request's own magnitude.
    pub fn with_precision(
        weights: &Weights,
        spec: &ConvSpec,
        algo: Algo,
        precision: Precision,
        act_scale: Option<f32>,
    ) -> PreparedWeights {
        let act = match act_scale {
            Some(s) => ActQuant::Static(s),
            None => ActQuant::Dynamic,
        };
        match (precision, algo) {
            (Precision::Int8, Algo::Im2col) => PreparedWeights {
                spec: spec.clone(),
                algo,
                kernel: PreparedKernel::QIm2col {
                    wt: PackedWtI8::quantize_wt(&im2col::weight_matrix(weights)),
                    act,
                },
            },
            (Precision::Int8, Algo::Kn2row) => {
                let mut taps = Vec::with_capacity(spec.k1 * spec.k2);
                for ky in 0..spec.k1 {
                    for kx in 0..spec.k2 {
                        taps.push(PackedWtI8::quantize_wt(&kn2row::unit_weight_matrix(
                            weights, ky, kx,
                        )));
                    }
                }
                PreparedWeights {
                    spec: spec.clone(),
                    algo,
                    kernel: PreparedKernel::QKn2row { taps, act },
                }
            }
            _ => PreparedWeights::new(weights, spec, algo),
        }
    }

    /// The precision this layer actually executes with (after any
    /// Winograd clamp).
    pub fn precision(&self) -> Precision {
        match self.kernel {
            PreparedKernel::QIm2col { .. } | PreparedKernel::QKn2row { .. } => Precision::Int8,
            _ => Precision::F32,
        }
    }

    /// Run the convolution on a prepared layer. Purely functional — no
    /// weight transform, no transpose, no cycle accounting.
    pub fn conv2d(&self, input: &Tensor) -> Tensor {
        match &self.kernel {
            PreparedKernel::Im2col { wt } => self.conv_im2col(input, wt),
            PreparedKernel::Kn2row { taps } => self.conv_kn2row(input, taps),
            PreparedKernel::Winograd { m, r, groups, u } => {
                self.conv_winograd(input, *m, *r, *groups, u)
            }
            PreparedKernel::Direct { weights } => {
                winograd::conv2d_strided(input, weights, &self.spec)
            }
            PreparedKernel::QIm2col { wt, act } => self.conv_qim2col(input, wt, *act),
            PreparedKernel::QKn2row { taps, act } => self.conv_qkn2row(input, taps, *act),
        }
    }

    /// Gather the im2col matrix in its transposed `(O1O2 × K1K2C_in)`
    /// orientation (each row is one window, built contiguously).
    fn im2col_matrix(&self, input: &Tensor) -> Mat {
        let spec = &self.spec;
        let (o1, o2) = (spec.o1(), spec.o2());
        let cols = spec.k1 * spec.k2 * spec.c_in;
        let mut xt = Mat::zeros(o1 * o2, cols);
        for oy in 0..o1 {
            for ox in 0..o2 {
                let row = (oy * o2 + ox) * cols;
                let iy0 = (oy * spec.s) as isize - spec.p1 as isize;
                let ix0 = (ox * spec.s) as isize - spec.p2 as isize;
                for ci in 0..spec.c_in {
                    for ky in 0..spec.k1 {
                        for kx in 0..spec.k2 {
                            xt.data[row + (ci * spec.k1 + ky) * spec.k2 + kx] = input
                                .get_padded(ci, iy0 + ky as isize, ix0 + kx as isize);
                        }
                    }
                }
            }
        }
        xt
    }

    /// im2col: gather + one GEMM, no transpose anywhere.
    fn conv_im2col(&self, input: &Tensor, wt: &PackedWt) -> Tensor {
        let spec = &self.spec;
        let (o1, o2) = (spec.o1(), spec.o2());
        let xt = self.im2col_matrix(input);
        let z = gemm(&xt, wt); // (O1O2 × C_out)
        Tensor::from_fn(spec.c_out, o1, o2, |c, y, x| z.get(y * o2 + x, c))
    }

    /// Quantized im2col: gather f32, quantize the whole Toeplitz matrix
    /// with one per-tensor scale, one int8 GEMM with fused f32
    /// requantization.
    fn conv_qim2col(&self, input: &Tensor, wt: &PackedWtI8, act: ActQuant) -> Tensor {
        let spec = &self.spec;
        let (o1, o2) = (spec.o1(), spec.o2());
        let xt = self.im2col_matrix(input);
        let xq = match act {
            ActQuant::Static(s) => QuantMat::quantize_scaled(&xt, s),
            ActQuant::Dynamic => QuantMat::quantize(&xt),
        };
        let z = qgemm(&xq, wt); // (O1O2 × C_out), requantized f32
        Tensor::from_fn(spec.c_out, o1, o2, |c, y, x| z.get(y * o2 + x, c))
    }

    /// kn2row: the `(H1H2 × C_in)` input matrix is tap-invariant — build
    /// it once, then one transpose-free GEMM + shifted accumulation per
    /// tap.
    fn conv_kn2row(&self, input: &Tensor, taps: &[PackedWt]) -> Tensor {
        let spec = &self.spec;
        let hw = spec.h1 * spec.h2;
        let xm_t = Mat::from_fn(hw, spec.c_in, |rc, ci| input.data[ci * hw + rc]);
        let mut acc = Tensor::zeros(spec.c_out, spec.o1(), spec.o2());
        for ky in 0..spec.k1 {
            for kx in 0..spec.k2 {
                let patch_t = gemm(&xm_t, &taps[ky * spec.k2 + kx]); // (H1H2 × C_out)
                kn2row::pad_accumulate_t(&mut acc, &patch_t, spec, ky, kx);
            }
        }
        acc
    }

    /// Quantized kn2row: quantize the tap-invariant input matrix once,
    /// then one int8 GEMM per tap; each tap requantizes to f32 before
    /// the shifted accumulation (i32 accumulate *within* a GEMM, f32
    /// accumulate *across* taps).
    fn conv_qkn2row(&self, input: &Tensor, taps: &[PackedWtI8], act: ActQuant) -> Tensor {
        let spec = &self.spec;
        let hw = spec.h1 * spec.h2;
        let xm_t = Mat::from_fn(hw, spec.c_in, |rc, ci| input.data[ci * hw + rc]);
        let xq = match act {
            ActQuant::Static(s) => QuantMat::quantize_scaled(&xm_t, s),
            ActQuant::Dynamic => QuantMat::quantize(&xm_t),
        };
        let mut acc = Tensor::zeros(spec.c_out, spec.o1(), spec.o2());
        for ky in 0..spec.k1 {
            for kx in 0..spec.k2 {
                let patch_t = qgemm(&xq, &taps[ky * spec.k2 + kx]); // (H1H2 × C_out)
                kn2row::pad_accumulate_t(&mut acc, &patch_t, spec, ky, kx);
            }
        }
        acc
    }

    /// Winograd: DLT-style tile gather + input transform per round, then
    /// the `(m+r−1)²` point GEMMs against the prepared `Uᵀ` panels,
    /// inverse transform and accumulate.
    fn conv_winograd(
        &self,
        input: &Tensor,
        m: usize,
        r: usize,
        groups: usize,
        u: &[Vec<PackedWt>],
    ) -> Tensor {
        let spec = &self.spec;
        let a = m + r - 1;
        let (o1, o2) = (spec.o1(), spec.o2());
        let t1 = o1.div_ceil(m);
        let t2 = o2.div_ceil(m);
        let tiles = t1 * t2;
        let mut out = Tensor::zeros(spec.c_out, o1, o2);
        for gy in 0..groups {
            for gx in 0..groups {
                // V tiles for every (channel, tile): gather + transform
                let mut v = vec![Mat::zeros(tiles, spec.c_in); a * a];
                for ci in 0..spec.c_in {
                    for ty in 0..t1 {
                        for tx in 0..t2 {
                            let iy0 = (ty * m + gy * r) as isize - spec.p1 as isize;
                            let ix0 = (tx * m + gx * r) as isize - spec.p2 as isize;
                            let d = Mat::from_fn(a, a, |y, x| {
                                input.get_padded(ci, iy0 + y as isize, ix0 + x as isize)
                            });
                            let vt = winograd::transform_input(&d);
                            for py in 0..a {
                                for px in 0..a {
                                    v[py * a + px].set(ty * t2 + tx, ci, vt.get(py, px));
                                }
                            }
                        }
                    }
                }
                // (m+r−1)² independent (tiles × C_in) · (C_in × C_out)
                let u_round = &u[gy * groups + gx];
                let m_pts: Vec<Mat> =
                    (0..a * a).map(|p| gemm(&v[p], &u_round[p])).collect();
                // inverse transform + accumulate into the output
                for co in 0..spec.c_out {
                    for ty in 0..t1 {
                        for tx in 0..t2 {
                            let mm = Mat::from_fn(a, a, |py, px| {
                                m_pts[py * a + px].get(ty * t2 + tx, co)
                            });
                            let y = winograd::inverse_transform(&mm);
                            for dy in 0..m {
                                for dx in 0..m {
                                    let (oy, ox) = (ty * m + dy, tx * m + dx);
                                    if oy < o1 && ox < o2 {
                                        let cur = out.get(co, oy, ox);
                                        out.set(co, oy, ox, cur + y.get(dy, dx));
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::direct;
    use crate::util::proptest::{assert_allclose, check};
    use crate::util::rng::Rng;

    #[test]
    fn im2col_prepared_exact_vs_direct() {
        check("prepared_im2col_vs_direct", 48, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            let input = Tensor::random_i8(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random_i8(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let pw = PreparedWeights::new(&w, &spec, Algo::Im2col);
            let out = pw.conv2d(&input);
            let reference = direct::conv2d(&input, &w, &spec);
            if out.data != reference.data {
                return Err(format!("mismatch for spec {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn kn2row_prepared_exact_vs_direct() {
        check("prepared_kn2row_vs_direct", 48, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            let input = Tensor::random_i8(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random_i8(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let pw = PreparedWeights::new(&w, &spec, Algo::Kn2row);
            let out = pw.conv2d(&input);
            let reference = direct::conv2d(&input, &w, &spec);
            if out.data != reference.data {
                return Err(format!("mismatch for spec {spec:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn winograd_prepared_matches_direct() {
        check("prepared_wino_vs_direct", 24, |r: &mut Rng| {
            let k = *r.choose(&[3usize, 5]);
            let h = r.range(k + 1, 11);
            let spec = ConvSpec::new(
                r.range(1, 3),
                r.range(1, 3),
                h,
                h,
                k,
                k,
                1,
                k / 2,
                k / 2,
            );
            let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random(spec.c_out, spec.c_in, k, k, r);
            let pw = PreparedWeights::new(&w, &spec, Algo::Winograd { m: 2, r: 3 });
            let out = pw.conv2d(&input);
            let reference = direct::conv2d(&input, &w, &spec);
            assert_allclose(&out.data, &reference.data, 1e-2, 1e-3)
                .map_err(|e| format!("spec {spec:?}: {e}"))
        });
    }

    #[test]
    fn strided_fallback_matches_direct() {
        let spec = ConvSpec::new(2, 3, 9, 9, 3, 3, 2, 1, 1);
        let mut r = Rng::new(21);
        let input = Tensor::random(2, 9, 9, &mut r);
        let w = Weights::random(3, 2, 3, 3, &mut r);
        let pw = PreparedWeights::new(&w, &spec, Algo::WinogradStrided { m: 2, r: 3 });
        let out = pw.conv2d(&input);
        let reference = direct::conv2d(&input, &w, &spec);
        assert_allclose(&out.data, &reference.data, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn quantized_prepared_close_to_f32_reference() {
        // int8 im2col/kn2row vs the f32 direct reference: within the
        // documented 5%-of-range tolerance on random data
        check("prepared_quant_vs_direct", 32, |r: &mut Rng| {
            let spec = im2col::random_spec(r);
            let input = Tensor::random(spec.c_in, spec.h1, spec.h2, r);
            let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, r);
            let reference = direct::conv2d(&input, &w, &spec);
            let fmax = reference.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            for algo in [Algo::Im2col, Algo::Kn2row] {
                let pw = PreparedWeights::with_precision(
                    &w,
                    &spec,
                    algo,
                    Precision::Int8,
                    None,
                );
                assert_eq!(pw.precision(), Precision::Int8);
                let out = pw.conv2d(&input);
                for (i, (a, b)) in out.data.iter().zip(&reference.data).enumerate() {
                    if (a - b).abs() > 0.05 * fmax {
                        return Err(format!(
                            "{algo:?} spec {spec:?} elem {i}: |{a} - {b}| > 5% of {fmax}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn winograd_int8_clamps_to_f32() {
        let spec = ConvSpec::new(2, 3, 8, 8, 3, 3, 1, 1, 1);
        let mut r = Rng::new(30);
        let w = Weights::random(3, 2, 3, 3, &mut r);
        let pw = PreparedWeights::with_precision(
            &w,
            &spec,
            Algo::Winograd { m: 2, r: 3 },
            Precision::Int8,
            None,
        );
        assert_eq!(pw.precision(), Precision::F32, "winograd must stay f32");
        assert!(matches!(pw.kernel, PreparedKernel::Winograd { .. }));
    }

    #[test]
    fn static_act_scale_is_deterministic_across_requests() {
        // with a calibrated scale, two different inputs quantize onto
        // the same grid; with dynamic, each input picks its own scale —
        // both must stay within tolerance of f32
        let spec = ConvSpec::new(3, 4, 8, 8, 3, 3, 1, 1, 1);
        let mut r = Rng::new(31);
        let w = Weights::random(4, 3, 3, 3, &mut r);
        let quant =
            PreparedWeights::with_precision(&w, &spec, Algo::Im2col, Precision::Int8, Some(1.0 / 127.0));
        for _ in 0..2 {
            let input = Tensor::random(3, 8, 8, &mut r);
            let out = quant.conv2d(&input);
            let reference = direct::conv2d(&input, &w, &spec);
            let fmax =
                reference.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-6);
            for (a, b) in out.data.iter().zip(&reference.data) {
                assert!((a - b).abs() <= 0.05 * fmax, "{a} vs {b} (range {fmax})");
            }
        }
    }

    #[test]
    fn prepare_is_request_invariant() {
        // the whole point: one prepare, many inputs
        let spec = ConvSpec::new(3, 4, 8, 8, 3, 3, 1, 1, 1);
        let mut r = Rng::new(22);
        let w = Weights::random(4, 3, 3, 3, &mut r);
        let pw = PreparedWeights::new(&w, &spec, Algo::Kn2row);
        for _ in 0..3 {
            let input = Tensor::random(3, 8, 8, &mut r);
            let out = pw.conv2d(&input);
            let reference = direct::conv2d(&input, &w, &spec);
            assert_allclose(&out.data, &reference.data, 1e-4, 1e-4).unwrap();
        }
    }
}
