//! Explicit-SIMD f32 GEMM microkernels with double-buffered panel
//! packing — the top tier of the kernel layer.
//!
//! [`gemm`] computes `X (a×b) · W (b×c)` like [`super::gemm::gemm`],
//! but through per-architecture microkernels chosen by the one-time CPU
//! probe ([`super::select`]): AVX2 on x86-64, NEON on AArch64, and a
//! portable scalar tile everywhere else (or under `DYNAMAP_SIMD=off`).
//!
//! # Bit-exactness
//!
//! Every output element accumulates its dot product in ascending-`k`
//! order with a *separate* IEEE-754 multiply and add per step — exactly
//! the operation sequence of [`Mat::matmul`]. The microkernels earn
//! their speed by vectorizing **across output columns**: each vector
//! lane is one column's independent accumulator, so widening the tile
//! never reassociates a sum. FMA is deliberately not used — its single
//! rounding per multiply-add would change low bits and break the
//! bit-identical contract the hot-swap, parallel-batch and
//! wire-bitwise tests rely on. `rust/tests/kernels.rs` fuzzes this
//! claim over ragged and degenerate shapes for every selectable kernel.
//!
//! # Packing and double buffering
//!
//! `W` arrives as the layer-lifetime [`PackedWt`] (column-major `Wᵀ`);
//! per call, columns are regrouped into `nc`-wide *panel groups* laid
//! out `k`-major so one tile step loads `nr` consecutive lane weights.
//! Groups are packed one step ahead of the compute on a scoped helper
//! thread ([`double_buffered`]) — the software analogue of the paper's
//! §3.3 off-chip/on-chip transfer overlap — and fall back to a
//! sequential pack-then-compute loop when `DYNAMAP_THREADS=1` or the
//! GEMM has a single group.
#![deny(clippy::correctness, clippy::suspicious)]
#![warn(missing_docs)]

use super::gemm::PackedWt;
use super::select::{KernelChoice, KernelKind, KernelSelector};
use crate::algos::tensor::Mat;
use crate::util::parallel::double_buffered;

/// Widest supported register tile: 4 rows × 16 columns (AVX2).
const MAX_MR: usize = 4;
/// Widest supported lane count (AVX2: two 256-bit registers).
const MAX_NR: usize = 16;

/// `X (a×b) · W (b×c)` through the probed, shape-selected microkernel.
/// Bit-identical to [`Mat::matmul`] and to [`super::gemm::gemm`].
/// Panics on a depth mismatch.
pub fn gemm(x: &Mat, w: &PackedWt) -> Mat {
    gemm_with(x, w, &KernelSelector::probed().choose(x.rows, x.cols, w.c))
}

/// [`gemm`] with an explicit kernel choice (tests sweep every
/// selectable kernel through this; the selector owns the default).
/// Panics if `choice` names a kind the host cannot execute.
pub fn gemm_with(x: &Mat, w: &PackedWt, choice: &KernelChoice) -> Mat {
    assert_eq!(x.cols, w.b, "kernels::simd::gemm depth mismatch");
    assert!(
        choice.kind.available(super::select::cpu_caps()) || choice.kind == KernelKind::Scalar,
        "kernel kind {:?} not executable on this host",
        choice.kind
    );
    let (a, b, c) = (x.rows, x.cols, w.c);
    let mut out = Mat::zeros(a, c);
    if a == 0 || c == 0 {
        return out;
    }
    let (nr, nc) = (choice.nr, choice.nc);
    let n_groups = c.div_ceil(nc);
    double_buffered(
        n_groups,
        |g| pack_group(w, g * nc, nc.min(c - g * nc), nr),
        |_, group| compute_group(x, b, &group, choice, &mut out),
    );
    out
}

/// One packed group of `cols ≤ nc` consecutive columns of `W`, split
/// into `nr`-wide panels laid out `panel → k → lane`; tail lanes past
/// `cols` are zero-filled (their tile results are computed and
/// discarded — zero weights never affect live lanes).
struct PanelGroup {
    /// First output column the group covers.
    j0: usize,
    /// Live columns in the group.
    cols: usize,
    /// `cols.div_ceil(nr) · b · nr` floats, panel-major.
    data: Vec<f32>,
}

fn pack_group(w: &PackedWt, j0: usize, cols: usize, nr: usize) -> PanelGroup {
    let b = w.b;
    let n_panels = cols.div_ceil(nr);
    let mut data = vec![0.0f32; n_panels * b * nr];
    for p in 0..n_panels {
        let base = p * b * nr;
        for l in 0..nr.min(cols - p * nr) {
            let col = w.col(j0 + p * nr + l);
            for (k, &v) in col.iter().enumerate() {
                data[base + k * nr + l] = v;
            }
        }
    }
    PanelGroup { j0, cols, data }
}

/// Run the chosen microkernel over every (row-block, panel) tile of one
/// packed group, scattering the live lanes into `out`.
fn compute_group(x: &Mat, b: usize, group: &PanelGroup, choice: &KernelChoice, out: &mut Mat) {
    let a = x.rows;
    let c = out.cols;
    let nr = choice.nr;
    let n_panels = group.cols.div_ceil(nr);
    let mut i = 0;
    while i < a {
        let mr = if choice.mr == MAX_MR && i + MAX_MR <= a { MAX_MR } else { 1 };
        for p in 0..n_panels {
            let j = group.j0 + p * nr;
            let vc = nr.min(group.j0 + group.cols - j);
            let panel = &group.data[p * b * nr..(p + 1) * b * nr];
            let mut tile = [0.0f32; MAX_MR * MAX_NR];
            run_tile(x, i, mr, b, panel, nr, choice.kind, &mut tile);
            for r in 0..mr {
                out.data[(i + r) * c + j..(i + r) * c + j + vc]
                    .copy_from_slice(&tile[r * nr..r * nr + vc]);
            }
        }
        i += mr;
    }
}

/// Dispatch one `mr × nr` tile to the architecture kernel. `tile` is
/// the row-major `mr × nr` destination scratch.
fn run_tile(
    x: &Mat,
    i: usize,
    mr: usize,
    b: usize,
    panel: &[f32],
    nr: usize,
    kind: KernelKind,
    tile: &mut [f32; MAX_MR * MAX_NR],
) {
    let row = |r: usize| &x.data[(i + r) * b..(i + r + 1) * b];
    match kind {
        KernelKind::Avx2 => run_avx2(row, mr, panel, tile),
        KernelKind::Neon => run_neon(row, mr, panel, tile),
        KernelKind::Scalar => {
            debug_assert_eq!(nr, 8, "scalar tile is fixed 8 lanes wide");
            if mr == MAX_MR {
                scalar::tile::<MAX_MR>([row(0), row(1), row(2), row(3)], panel, tile);
            } else {
                scalar::tile::<1>([row(0)], panel, tile);
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn run_avx2<'a>(
    row: impl Fn(usize) -> &'a [f32],
    mr: usize,
    panel: &[f32],
    tile: &mut [f32; MAX_MR * MAX_NR],
) {
    // SAFETY: Avx2 is only ever chosen (or accepted by `gemm_with`)
    // when the probe reported AVX2 support on this host.
    unsafe {
        if mr == MAX_MR {
            avx2::tile4(row(0), row(1), row(2), row(3), panel, tile);
        } else {
            avx2::tile1(row(0), panel, tile);
        }
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn run_avx2<'a>(
    _row: impl Fn(usize) -> &'a [f32],
    _mr: usize,
    _panel: &[f32],
    _tile: &mut [f32; MAX_MR * MAX_NR],
) {
    unreachable!("AVX2 kernel selected on a non-x86-64 host");
}

#[cfg(target_arch = "aarch64")]
fn run_neon<'a>(
    row: impl Fn(usize) -> &'a [f32],
    mr: usize,
    panel: &[f32],
    tile: &mut [f32; MAX_MR * MAX_NR],
) {
    // SAFETY: NEON is baseline on every AArch64 std target.
    unsafe {
        if mr == MAX_MR {
            neon::tile4(row(0), row(1), row(2), row(3), panel, tile);
        } else {
            neon::tile1(row(0), panel, tile);
        }
    }
}

#[cfg(not(target_arch = "aarch64"))]
fn run_neon<'a>(
    _row: impl Fn(usize) -> &'a [f32],
    _mr: usize,
    _panel: &[f32],
    _tile: &mut [f32; MAX_MR * MAX_NR],
) {
    unreachable!("NEON kernel selected on a non-AArch64 host");
}

/// Portable scalar tile, fixed 8 lanes wide. Each lane `l` of each row
/// accumulates `Σ_k x[k] · w[k][l]` in ascending `k` with separate
/// mul/add — the compiler may auto-vectorize the lane loop, which
/// preserves per-lane IEEE semantics and therefore bitwise results.
mod scalar {
    use super::{MAX_MR, MAX_NR};

    pub fn tile<const MR: usize>(
        xs: [&[f32]; MR],
        panel: &[f32],
        tile: &mut [f32; MAX_MR * MAX_NR],
    ) {
        const NR: usize = 8;
        let b = xs[0].len();
        let mut acc = [[0.0f32; NR]; MR];
        for k in 0..b {
            let w = &panel[k * NR..k * NR + NR];
            for r in 0..MR {
                let xv = xs[r][k];
                for l in 0..NR {
                    acc[r][l] += xv * w[l];
                }
            }
        }
        for r in 0..MR {
            tile[r * NR..r * NR + NR].copy_from_slice(&acc[r]);
        }
    }
}

/// AVX2 tiles, 16 lanes wide (two 256-bit registers per row). Separate
/// `_mm256_mul_ps` + `_mm256_add_ps` per step — never FMA — keeps every
/// lane bit-identical to the scalar reference.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{MAX_MR, MAX_NR};
    use std::arch::x86_64::*;

    /// 4×16 tile.
    ///
    /// # Safety
    /// Requires AVX2. `panel` must hold `b · 16` floats where
    /// `b = x0.len() = x1.len() = x2.len() = x3.len()`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile4(
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
        panel: &[f32],
        tile: &mut [f32; MAX_MR * MAX_NR],
    ) {
        let b = x0.len();
        debug_assert!(panel.len() >= b * 16);
        let mut a00 = _mm256_setzero_ps();
        let mut a01 = _mm256_setzero_ps();
        let mut a10 = _mm256_setzero_ps();
        let mut a11 = _mm256_setzero_ps();
        let mut a20 = _mm256_setzero_ps();
        let mut a21 = _mm256_setzero_ps();
        let mut a30 = _mm256_setzero_ps();
        let mut a31 = _mm256_setzero_ps();
        for k in 0..b {
            let w0 = _mm256_loadu_ps(panel.as_ptr().add(k * 16));
            let w1 = _mm256_loadu_ps(panel.as_ptr().add(k * 16 + 8));
            let v0 = _mm256_set1_ps(*x0.get_unchecked(k));
            a00 = _mm256_add_ps(a00, _mm256_mul_ps(v0, w0));
            a01 = _mm256_add_ps(a01, _mm256_mul_ps(v0, w1));
            let v1 = _mm256_set1_ps(*x1.get_unchecked(k));
            a10 = _mm256_add_ps(a10, _mm256_mul_ps(v1, w0));
            a11 = _mm256_add_ps(a11, _mm256_mul_ps(v1, w1));
            let v2 = _mm256_set1_ps(*x2.get_unchecked(k));
            a20 = _mm256_add_ps(a20, _mm256_mul_ps(v2, w0));
            a21 = _mm256_add_ps(a21, _mm256_mul_ps(v2, w1));
            let v3 = _mm256_set1_ps(*x3.get_unchecked(k));
            a30 = _mm256_add_ps(a30, _mm256_mul_ps(v3, w0));
            a31 = _mm256_add_ps(a31, _mm256_mul_ps(v3, w1));
        }
        let t = tile.as_mut_ptr();
        _mm256_storeu_ps(t, a00);
        _mm256_storeu_ps(t.add(8), a01);
        _mm256_storeu_ps(t.add(16), a10);
        _mm256_storeu_ps(t.add(24), a11);
        _mm256_storeu_ps(t.add(32), a20);
        _mm256_storeu_ps(t.add(40), a21);
        _mm256_storeu_ps(t.add(48), a30);
        _mm256_storeu_ps(t.add(56), a31);
    }

    /// 1×16 remainder-row tile.
    ///
    /// # Safety
    /// Requires AVX2. `panel` must hold `x0.len() · 16` floats.
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile1(x0: &[f32], panel: &[f32], tile: &mut [f32; MAX_MR * MAX_NR]) {
        let b = x0.len();
        debug_assert!(panel.len() >= b * 16);
        let mut a00 = _mm256_setzero_ps();
        let mut a01 = _mm256_setzero_ps();
        for k in 0..b {
            let w0 = _mm256_loadu_ps(panel.as_ptr().add(k * 16));
            let w1 = _mm256_loadu_ps(panel.as_ptr().add(k * 16 + 8));
            let v0 = _mm256_set1_ps(*x0.get_unchecked(k));
            a00 = _mm256_add_ps(a00, _mm256_mul_ps(v0, w0));
            a01 = _mm256_add_ps(a01, _mm256_mul_ps(v0, w1));
        }
        _mm256_storeu_ps(tile.as_mut_ptr(), a00);
        _mm256_storeu_ps(tile.as_mut_ptr().add(8), a01);
    }
}

/// NEON tiles, 8 lanes wide (two 128-bit registers per row). Separate
/// `vmulq_f32` + `vaddq_f32` per step — never FMA.
#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{MAX_MR, MAX_NR};
    use std::arch::aarch64::*;

    /// 4×8 tile.
    ///
    /// # Safety
    /// `panel` must hold `b · 8` floats where `b` is the shared row
    /// length (NEON itself is baseline on AArch64).
    pub unsafe fn tile4(
        x0: &[f32],
        x1: &[f32],
        x2: &[f32],
        x3: &[f32],
        panel: &[f32],
        tile: &mut [f32; MAX_MR * MAX_NR],
    ) {
        let b = x0.len();
        debug_assert!(panel.len() >= b * 8);
        let mut a00 = vdupq_n_f32(0.0);
        let mut a01 = vdupq_n_f32(0.0);
        let mut a10 = vdupq_n_f32(0.0);
        let mut a11 = vdupq_n_f32(0.0);
        let mut a20 = vdupq_n_f32(0.0);
        let mut a21 = vdupq_n_f32(0.0);
        let mut a30 = vdupq_n_f32(0.0);
        let mut a31 = vdupq_n_f32(0.0);
        for k in 0..b {
            let w0 = vld1q_f32(panel.as_ptr().add(k * 8));
            let w1 = vld1q_f32(panel.as_ptr().add(k * 8 + 4));
            let v0 = vdupq_n_f32(*x0.get_unchecked(k));
            a00 = vaddq_f32(a00, vmulq_f32(v0, w0));
            a01 = vaddq_f32(a01, vmulq_f32(v0, w1));
            let v1 = vdupq_n_f32(*x1.get_unchecked(k));
            a10 = vaddq_f32(a10, vmulq_f32(v1, w0));
            a11 = vaddq_f32(a11, vmulq_f32(v1, w1));
            let v2 = vdupq_n_f32(*x2.get_unchecked(k));
            a20 = vaddq_f32(a20, vmulq_f32(v2, w0));
            a21 = vaddq_f32(a21, vmulq_f32(v2, w1));
            let v3 = vdupq_n_f32(*x3.get_unchecked(k));
            a30 = vaddq_f32(a30, vmulq_f32(v3, w0));
            a31 = vaddq_f32(a31, vmulq_f32(v3, w1));
        }
        let t = tile.as_mut_ptr();
        vst1q_f32(t, a00);
        vst1q_f32(t.add(4), a01);
        vst1q_f32(t.add(8), a10);
        vst1q_f32(t.add(12), a11);
        vst1q_f32(t.add(16), a20);
        vst1q_f32(t.add(20), a21);
        vst1q_f32(t.add(24), a30);
        vst1q_f32(t.add(28), a31);
    }

    /// 1×8 remainder-row tile.
    ///
    /// # Safety
    /// `panel` must hold `x0.len() · 8` floats.
    pub unsafe fn tile1(x0: &[f32], panel: &[f32], tile: &mut [f32; MAX_MR * MAX_NR]) {
        let b = x0.len();
        debug_assert!(panel.len() >= b * 8);
        let mut a00 = vdupq_n_f32(0.0);
        let mut a01 = vdupq_n_f32(0.0);
        for k in 0..b {
            let w0 = vld1q_f32(panel.as_ptr().add(k * 8));
            let w1 = vld1q_f32(panel.as_ptr().add(k * 8 + 4));
            let v0 = vdupq_n_f32(*x0.get_unchecked(k));
            a00 = vaddq_f32(a00, vmulq_f32(v0, w0));
            a01 = vaddq_f32(a01, vmulq_f32(v0, w1));
        }
        vst1q_f32(tile.as_mut_ptr(), a00);
        vst1q_f32(tile.as_mut_ptr().add(4), a01);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::select::CpuCaps;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_mat(r: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| r.f32_range(-1.0, 1.0))
    }

    #[test]
    fn probed_path_matches_matmul_bitwise() {
        check("simd_gemm_vs_matmul", 64, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 40), r.range(1, 40), r.range(1, 200));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            let fast = gemm(&x, &PackedWt::pack(&w));
            if fast.data != x.matmul(&w).data {
                return Err(format!("bitwise mismatch for ({a},{b},{c})"));
            }
            Ok(())
        });
    }

    #[test]
    fn every_kind_matches_on_a_remainder_heavy_shape() {
        // 5×7×19: rows leave an mr=4 remainder, 19 columns leave tail
        // lanes in every lane width, and with nc = nr the GEMM spans
        // multiple double-buffered groups
        let mut r = Rng::new(7);
        let x = random_mat(&mut r, 5, 7);
        let w = random_mat(&mut r, 7, 19);
        let packed = PackedWt::pack(&w);
        let reference = x.matmul(&w);
        for kind in KernelSelector::probed().kinds() {
            for mr in [1, 4] {
                let mut choice = KernelChoice::of(kind, mr, 7);
                choice.nc = choice.nr;
                let out = gemm_with(&x, &packed, &choice);
                assert_eq!(out.data, reference.data, "{}", choice.name());
            }
        }
    }

    #[test]
    fn zero_depth_yields_zeros() {
        let x = Mat::zeros(3, 0);
        let w = PackedWt::pack(&Mat::zeros(0, 9));
        let out = gemm(&x, &w);
        assert_eq!(out, Mat::zeros(3, 9));
        assert_eq!(out.data, x.matmul(&w.as_wt_mat().transposed()).data);
    }

    #[test]
    fn empty_output_shapes() {
        assert_eq!(gemm(&Mat::zeros(0, 4), &PackedWt::pack(&Mat::zeros(4, 6))), Mat::zeros(0, 6));
        assert_eq!(gemm(&Mat::zeros(4, 4), &PackedWt::pack(&Mat::zeros(4, 0))), Mat::zeros(4, 0));
    }

    #[test]
    fn scalar_fallback_matches_packed_kernel() {
        let sel = KernelSelector::new(CpuCaps::scalar());
        check("simd_scalar_vs_packed", 32, |r: &mut Rng| {
            let (a, b, c) = (r.range(1, 20), r.range(1, 20), r.range(1, 40));
            let x = random_mat(r, a, b);
            let w = random_mat(r, b, c);
            let packed = PackedWt::pack(&w);
            let simd = gemm_with(&x, &packed, &sel.choose(a, b, c));
            if simd.data != super::super::gemm::gemm(&x, &packed).data {
                return Err(format!("scalar fallback mismatch for ({a},{b},{c})"));
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "depth mismatch")]
    fn depth_mismatch_panics() {
        gemm(&Mat::zeros(2, 3), &PackedWt::pack(&Mat::zeros(4, 2)));
    }
}
