//! CPU capability probe + per-shape kernel selection for the SIMD
//! microkernel tier ([`super::simd`]).
//!
//! The probe ([`cpu_caps`]) runs once per process and answers "which
//! instruction sets does this host have"; the [`KernelSelector`] then
//! picks a microkernel and tile shape *per GEMM shape* from a small
//! static throughput table — the software analogue of DYNAMAP picking a
//! dataflow per layer on a fixed overlay. Selection is a pure function
//! of `(capabilities, shape)`: no timing feeds the *choice*, so the
//! same host always produces the same kernel for the same layer (plans
//! and serving stay deterministic). Measured throughput enters the
//! picture only through [`KernelSelector::measure`], which produces a
//! [`KernelThroughput`] table for the *cost model* — the DSE prices
//! layers with what the host was measured to run, while the runtime
//! choice stays table-driven and reproducible.
//!
//! `DYNAMAP_SIMD=off` (or `scalar`/`0`) forces the portable scalar
//! fallback, for debugging and for the CI leg that keeps the fallback
//! green on SIMD-capable runners.
#![deny(clippy::correctness, clippy::suspicious)]
#![warn(missing_docs)]

use std::sync::OnceLock;
use std::time::Instant;

use super::gemm::PackedWt;
use crate::algos::tensor::Mat;
use crate::cost::device::KernelThroughput;

/// Instruction-set capabilities of the host, as seen by the kernel
/// tier. Constructed by [`CpuCaps::detect`] in production; tests build
/// instances directly to exercise every fallback path without mutating
/// process-global environment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuCaps {
    /// x86-64 AVX2 (256-bit, 8 f32 lanes per register).
    pub avx2: bool,
    /// AArch64 NEON (128-bit, 4 f32 lanes per register).
    pub neon: bool,
}

impl CpuCaps {
    /// Probe the hardware and apply the `DYNAMAP_SIMD` override.
    pub fn detect() -> CpuCaps {
        CpuCaps::from_env_value(std::env::var("DYNAMAP_SIMD").ok().as_deref())
    }

    /// The raw hardware probe, ignoring the environment.
    pub fn host() -> CpuCaps {
        CpuCaps { avx2: host_avx2(), neon: host_neon() }
    }

    /// No SIMD at all: every shape runs the portable scalar microkernel.
    pub fn scalar() -> CpuCaps {
        CpuCaps { avx2: false, neon: false }
    }

    /// The probe as a function of the `DYNAMAP_SIMD` value — the env
    /// hook, factored so tests can drive it with explicit values
    /// instead of racing on `set_var` across test threads.
    /// `off`/`scalar`/`0` force the scalar fallback; anything else
    /// (including unset) keeps the hardware probe.
    pub fn from_env_value(simd: Option<&str>) -> CpuCaps {
        match simd.map(str::trim) {
            Some("off") | Some("scalar") | Some("0") => CpuCaps::scalar(),
            _ => CpuCaps::host(),
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn host_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

#[cfg(not(target_arch = "x86_64"))]
fn host_avx2() -> bool {
    false
}

/// NEON is baseline on AArch64 (std targets always enable it).
fn host_neon() -> bool {
    cfg!(target_arch = "aarch64")
}

/// The process-wide capability probe, run once and cached.
pub fn cpu_caps() -> CpuCaps {
    static CAPS: OnceLock<CpuCaps> = OnceLock::new();
    *CAPS.get_or_init(CpuCaps::detect)
}

/// Which microkernel family executes a GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// AVX2 intrinsics, 16 output columns per tile (two 256-bit
    /// registers).
    Avx2,
    /// NEON intrinsics, 8 output columns per tile (two 128-bit
    /// registers).
    Neon,
    /// Portable scalar fallback with fixed 8-wide lane arrays (the
    /// compiler may auto-vectorize it; per-lane semantics are identical
    /// either way).
    Scalar,
}

impl KernelKind {
    /// Display name (also the prefix of [`KernelChoice::name`]).
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
            KernelKind::Scalar => "scalar",
        }
    }

    /// Output columns one tile of this kind produces (`nr`).
    pub fn lanes(&self) -> usize {
        match self {
            KernelKind::Avx2 => 16,
            KernelKind::Neon | KernelKind::Scalar => 8,
        }
    }

    /// Static sustained-throughput estimate in f32 FLOPs/cycle, used
    /// only to *rank* kinds in [`KernelSelector::choose`] (never as a
    /// latency — measured numbers live in [`KernelThroughput`]).
    fn flops_per_cycle(&self) -> f64 {
        match self {
            KernelKind::Avx2 => 24.0,
            KernelKind::Neon => 10.0,
            KernelKind::Scalar => 2.5,
        }
    }

    /// Is this kind executable under `caps`?
    pub fn available(&self, caps: CpuCaps) -> bool {
        match self {
            KernelKind::Avx2 => caps.avx2,
            KernelKind::Neon => caps.neon,
            KernelKind::Scalar => true,
        }
    }
}

/// A fully-resolved kernel choice for one GEMM shape: microkernel kind
/// plus tile geometry. `mr × nr` is the register tile (rows × output
/// columns); `nc` is the column-panel group width the packer builds
/// ahead of the compute (see `super::simd`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelChoice {
    /// Microkernel family.
    pub kind: KernelKind,
    /// Register-tile rows (1 or 4).
    pub mr: usize,
    /// Register-tile output columns (the kind's lane count).
    pub nr: usize,
    /// Columns per packed panel group (multiple of `nr`).
    pub nc: usize,
}

impl KernelChoice {
    /// A choice for `kind` with its natural tile (`mr` ∈ {1, 4}) and a
    /// default panel-group width for reduction depth `b`.
    pub fn of(kind: KernelKind, mr: usize, b: usize) -> KernelChoice {
        assert!(mr == 1 || mr == 4, "microkernel tier implements mr ∈ {{1, 4}}");
        let nr = kind.lanes();
        KernelChoice { kind, mr, nr, nc: default_nc(b, nr) }
    }

    /// Stable name, e.g. `avx2-4x16` — the key space of
    /// [`KernelThroughput`].
    pub fn name(&self) -> String {
        format!("{}-{}x{}", self.kind.name(), self.mr, self.nr)
    }
}

/// Panel-group width targeting ~128 KiB of packed floats (L2-resident
/// next to the row block), rounded to a multiple of `nr` and clamped to
/// `[nr, 512]`.
fn default_nc(b: usize, nr: usize) -> usize {
    let target_cols = (128 * 1024 / 4) / b.max(1);
    let nc = (target_cols / nr).max(1) * nr;
    nc.clamp(nr, 512)
}

/// Shape-aware kernel selection over a fixed capability set.
#[derive(Debug, Clone, Copy)]
pub struct KernelSelector {
    caps: CpuCaps,
}

impl KernelSelector {
    /// A selector over explicit capabilities (tests force the fallback
    /// this way).
    pub fn new(caps: CpuCaps) -> KernelSelector {
        KernelSelector { caps }
    }

    /// A selector over the process-wide probe ([`cpu_caps`]).
    pub fn probed() -> KernelSelector {
        KernelSelector::new(cpu_caps())
    }

    /// The capabilities this selector chooses under.
    pub fn caps(&self) -> CpuCaps {
        self.caps
    }

    /// Kinds executable under the probe, best-ranked first. Scalar is
    /// always last (and always present).
    pub fn kinds(&self) -> Vec<KernelKind> {
        [KernelKind::Avx2, KernelKind::Neon, KernelKind::Scalar]
            .into_iter()
            .filter(|k| k.available(self.caps))
            .collect()
    }

    /// Pick the microkernel and tile shape for an `a × b × c` GEMM.
    /// Deterministic in `(caps, a, b, c)`: the ranking multiplies each
    /// kind's static FLOPs/cycle by its column-lane efficiency on `c`
    /// (tail lanes past `c` are packed as zeros and compute dead work),
    /// and ties break toward the earlier (wider) kind.
    pub fn choose(&self, a: usize, b: usize, c: usize) -> KernelChoice {
        let kind = self
            .kinds()
            .into_iter()
            // max_by keeps the *last* maximum, so iterate worst-first:
            // exact rate ties resolve to the best-ranked kind
            .rev()
            .max_by(|p, q| {
                let rate = |k: &KernelKind| k.flops_per_cycle() * lane_efficiency(c, k.lanes());
                rate(p).partial_cmp(&rate(q)).unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(KernelKind::Scalar);
        let mr = if a >= 4 { 4 } else { 1 };
        KernelChoice::of(kind, mr, b)
    }

    /// Time every selectable kernel on a fixed reference GEMM and
    /// return the measured-throughput table the cost model folds in
    /// ([`crate::cost::CostModel::microkernels`]). This is the only
    /// place wall-clock feeds the tier, and its output goes to the
    /// *pricing* side exclusively — runtime selection stays static.
    pub fn measure(&self) -> KernelThroughput {
        // reference shape: multiple of every tile (rows of 4, 16 lanes)
        // so the table records peak-tile throughput; shape-dependent
        // tail losses are re-applied analytically by `gemm_sec`
        let (a, b, c) = (96, 64, 128);
        let mut rng = crate::util::rng::Rng::new(99);
        let x = Mat::from_fn(a, b, |_, _| rng.f32_range(-1.0, 1.0));
        let w = PackedWt::pack(&Mat::from_fn(b, c, |_, _| rng.f32_range(-1.0, 1.0)));
        let flops = 2.0 * (a * b * c) as f64;
        let mut table = KernelThroughput::default();
        let mut best_gflops = 0.0f64;
        // the measurement itself is observable: each timed kernel emits
        // one `measure` span when a recorder is installed (crate::obs),
        // so a trace of session startup shows where calibration went
        let recorder = crate::obs::active();
        for kind in self.kinds() {
            for mr in [4usize, 1] {
                let choice = KernelChoice::of(kind, mr, b);
                let t0 = Instant::now();
                let gflops = flops * time_calls(|| super::simd::gemm_with(&x, &w, &choice)) / 1e9;
                if let Some(rec) = &recorder {
                    rec.record_span(
                        None,
                        crate::obs::Stage::Measure,
                        &choice.name(),
                        t0,
                        Instant::now(),
                        vec![("gflops", format!("{gflops:.2}"))],
                    );
                }
                if gflops > best_gflops {
                    best_gflops = gflops;
                }
                table = table.with(&choice.name(), gflops);
            }
        }
        // per-call overhead: what a near-zero-work GEMM costs beyond
        // its (negligible) modeled compute — dispatch, packing setup,
        // output allocation. Priced per GEMM *call*, which is exactly
        // the axis the algorithms differ on (1 im2col call vs K1K2
        // kn2row calls vs (m+r−1)²·rounds Winograd calls).
        let tiny_x = Mat::from_fn(1, 1, |_, _| 1.0);
        let tiny_w = PackedWt::pack(&Mat::from_fn(1, 1, |_, _| 1.0));
        let best = self.choose(1, 1, 1);
        let tiny_sec = 1.0 / time_calls(|| super::simd::gemm_with(&tiny_x, &tiny_w, &best));
        let modeled = 2.0 / (best_gflops.max(1e-9) * 1e9);
        table.call_overhead_sec = (tiny_sec - modeled).max(0.0);
        table
    }
}

/// Fraction of lanes doing live work for `c` output columns at lane
/// width `nr` (tail lanes are zero-packed and wasted).
fn lane_efficiency(c: usize, nr: usize) -> f64 {
    if c == 0 {
        return 1.0;
    }
    c as f64 / (c.div_ceil(nr) * nr) as f64
}

/// Calls per second of `f`, measured over a short fixed budget.
fn time_calls<R>(mut f: impl FnMut() -> R) -> f64 {
    // warm once (page in, fill caches), then run for ~2 ms or 64 calls,
    // whichever comes later — enough to average out timer granularity
    // without making `measure()` noticeable at session startup
    std::hint::black_box(f());
    let start = Instant::now();
    let mut calls = 0u32;
    loop {
        std::hint::black_box(f());
        calls += 1;
        if calls >= 64 && start.elapsed().as_secs_f64() >= 2e-3 {
            break;
        }
        if calls >= 4096 {
            break;
        }
    }
    calls as f64 / start.elapsed().as_secs_f64().max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available() {
        for caps in [CpuCaps::host(), CpuCaps::scalar()] {
            let kinds = KernelSelector::new(caps).kinds();
            assert_eq!(kinds.last(), Some(&KernelKind::Scalar));
        }
        assert_eq!(KernelSelector::new(CpuCaps::scalar()).kinds(), vec![KernelKind::Scalar]);
    }

    #[test]
    fn env_hook_forces_scalar() {
        for v in ["off", "scalar", "0", " off "] {
            assert_eq!(CpuCaps::from_env_value(Some(v)), CpuCaps::scalar());
        }
        assert_eq!(CpuCaps::from_env_value(None), CpuCaps::host());
        assert_eq!(CpuCaps::from_env_value(Some("on")), CpuCaps::host());
    }

    #[test]
    fn choice_geometry_is_sane() {
        let sel = KernelSelector::probed();
        for (a, b, c) in [(1, 1, 1), (3, 7, 5), (128, 96, 128), (0, 0, 0), (512, 2048, 512)] {
            let ch = sel.choose(a, b, c);
            assert_eq!(ch.nr, ch.kind.lanes());
            assert_eq!(ch.nc % ch.nr, 0, "nc must be a whole number of panels");
            assert!((ch.nr..=512).contains(&ch.nc));
            assert_eq!(ch.mr, if a >= 4 { 4 } else { 1 });
        }
    }

    #[test]
    fn scalar_selector_never_picks_simd() {
        let sel = KernelSelector::new(CpuCaps::scalar());
        for (a, b, c) in [(1, 1, 1), (64, 64, 64), (128, 96, 128)] {
            assert_eq!(sel.choose(a, b, c).kind, KernelKind::Scalar);
            assert_eq!(sel.choose(a, b, c).name(), format!("scalar-{}x8", if a >= 4 { 4 } else { 1 }));
        }
    }

    #[test]
    fn lane_efficiency_bounds() {
        assert_eq!(lane_efficiency(16, 16), 1.0);
        assert_eq!(lane_efficiency(8, 16), 0.5);
        assert_eq!(lane_efficiency(0, 16), 1.0);
        assert!((lane_efficiency(17, 16) - 17.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn measure_covers_every_selectable_kernel() {
        let sel = KernelSelector::new(CpuCaps::scalar());
        let table = sel.measure();
        assert!(!table.is_empty());
        assert!(table.gflops.contains_key("scalar-4x8"));
        assert!(table.gflops.contains_key("scalar-1x8"));
        assert!(table.gflops.values().all(|&g| g > 0.0));
        assert!(table.call_overhead_sec >= 0.0);
    }
}
