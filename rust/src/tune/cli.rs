//! `dynamap tune` — one-shot calibrate + re-map from a recorded
//! profile.
//!
//! Reads a profile JSON written by the `dynamap serve` REPL
//! (`profile <model> <file>`, available when serving with `--tune`),
//! fits the cost model to it, re-solves the DSE and prints the
//! calibration report, the algorithm-map diff and the predicted
//! speedup. With `--out` the calibrated plan artifact is persisted for
//! later `Session::builder(..).plan(..)` serving. No live registry is
//! involved: this is the offline half of the adaptation loop, useful
//! for inspecting what `serve --tune` would do before enabling it.

use crate::api::Compiler;
use crate::cost::Device;
use crate::graph::zoo;
use crate::util::cli::Args;
use crate::util::table::Table;

use super::calibrate::calibrate;
use super::profiler::LayerProfile;
use super::remap::plan_delta;
use super::report::observed_vs_predicted;

/// `dynamap tune --model <name> --profile <file> [--device small-edge]
/// [--hysteresis 0.05] [--quant] [--out <dir|file.json>]`.
pub fn tune(args: &Args) -> i32 {
    let model = args.get_or("model", "mini-inception");
    let Some(cnn) = zoo::by_name(model) else {
        eprintln!("error: unknown model '{model}' (see `dynamap zoo`)");
        return 1;
    };
    let Some(profile_path) = args.get("profile") else {
        eprintln!(
            "usage: dynamap tune --model <name> --profile <file.json> \
             [--device small-edge|alveo-u200] [--hysteresis 0.05] [--out <dir|file>]\n\
             record a profile first: `dynamap serve --models <name> --tune`, then \
             `profile <name> <file.json>` in the REPL"
        );
        return 2;
    };
    let profile = match LayerProfile::load(profile_path) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error loading profile: {e}");
            return 1;
        }
    };
    let device = match args.get_or("device", "alveo-u200") {
        "small-edge" | "small_edge" => Device::small_edge(),
        "alveo-u200" | "alveo_u200" => Device::alveo_u200(),
        other => {
            // calibrating a profile against the wrong device produces
            // confidently wrong fits — refuse rather than guess
            eprintln!("error: unknown device '{other}' (small-edge | alveo-u200)");
            return 2;
        }
    };
    // --quant: keep the precision axis in the re-solve, so a profile
    // recorded under a quantized plan re-maps in the same search space
    let compiler = Compiler::new().device(device).precision_search(args.has("quant"));

    // base plan: what the uncalibrated model would serve
    let base = match compiler.compile(&cnn) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let (p1, p2) = (base.plan.p1, base.plan.p2);
    let base_map: std::collections::BTreeMap<String, String> = base
        .plan
        .mapping
        .layers
        .iter()
        .map(|l| {
            (
                l.name.clone(),
                crate::quant::mapped_name(l.cost.algo.family(), l.cost.precision),
            )
        })
        .collect();
    let snapshot = profile.snapshot();
    println!(
        "{}",
        observed_vs_predicted(&cnn, &compiler, p1, p2, &base_map, &snapshot).render()
    );

    let cal = match calibrate(&cnn, &compiler, p1, p2, &snapshot) {
        Ok(cal) => cal,
        Err(e) => {
            eprintln!("calibration failed: {e}");
            return 1;
        }
    };
    println!("{}", cal.report());

    // calibrated re-solve + diff against the base plan, through the
    // same plan_delta decision a live `serve --tune` remap uses
    let calibrated_compiler =
        compiler.clone().device(cal.device.clone()).calibration(cal.calibration.clone());
    let artifact = match calibrated_compiler.compile(&cnn) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("calibrated compile failed: {e}");
            return 1;
        }
    };
    let delta = plan_delta(&cnn, &calibrated_compiler, &artifact, &base_map);
    if delta.changed.is_empty() {
        println!("calibrated re-solve keeps the base algorithm map unchanged");
    } else {
        let mut diff = Table::new(
            &format!("algorithm map diff ({} → calibrated)", cnn.name),
            &["layer", "base", "calibrated"],
        );
        for c in &delta.changed {
            diff.row(vec![c.layer.clone(), c.from.clone(), c.to.clone()]);
        }
        println!("{}", diff.render());
    }

    let hysteresis = args.get_f64("hysteresis", 0.05).clamp(0.0, 0.9);
    println!(
        "predicted compute under the calibrated model: {:.0}µs → {:.0}µs \
         ({:.2}x, hysteresis {hysteresis:.2} → {})",
        delta.predicted_before_us,
        delta.predicted_after_us,
        delta.predicted_speedup,
        if delta.improves(hysteresis) {
            "a live server would hot-swap"
        } else {
            "a live server would keep the current plan"
        }
    );

    if let Some(out) = args.get("out") {
        let path = if out.ends_with(".json") {
            std::path::PathBuf::from(out)
        } else {
            std::path::Path::new(out)
                .join(calibrated_compiler.cache_file_name(&cnn.name))
        };
        if let Err(e) = artifact.save(&path) {
            eprintln!("error saving calibrated plan: {e}");
            return 1;
        }
        println!("wrote calibrated plan artifact to {}", path.display());
    }
    0
}
