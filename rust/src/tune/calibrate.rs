//! Least-squares calibration of the analytic cost model against
//! observed per-layer latencies.
//!
//! fpgaConvNet-style DSE models stay predictive only when fitted to
//! measured performance. For each algorithm family this module fits an
//! affine correction `observed ≈ scale · analytic + offset` over the
//! profiled layers (ordinary least squares; falls back to a
//! through-origin fit when there are too few points for a stable
//! intercept). The result is a [`CalibratedDevice`]: the effective
//! device parameters — achievable per-family GEMM throughput, an
//! effective DDR bandwidth scaled by the global time-dilation factor so
//! compute and transition costs stay commensurate — plus an
//! observed-vs-predicted residual report. Feeding its
//! [`DeviceCalibration`] back into a [`Compiler`] re-prices the whole
//! DSE in observed time units, which is what `tune::remap` re-solves.

use std::collections::BTreeMap;

use crate::api::session::resolve_algo;
use crate::api::{Compiler, DynamapError};
use crate::cost::conv::CostModel;
use crate::cost::{AlgoFit, Device, DeviceCalibration};
use crate::graph::layer::{ConvSpec, Op};
use crate::graph::Cnn;
use crate::util::table::Table;

use super::profiler::LayerObs;

/// Per-key fit summary in a [`CalibratedDevice`] report.
#[derive(Debug, Clone)]
pub struct AlgoFitReport {
    /// Fit key: algorithm family, precision-suffixed when the
    /// observations came from a quantized layer ("im2col-int8").
    pub family: String,
    /// Profiled layers behind the fit.
    pub points: usize,
    /// Fitted multiplicative term (observed / analytic time-scale).
    pub scale: f64,
    /// Fitted per-layer overhead, µs.
    pub offset_us: f64,
    /// Mean |observed − calibrated-predicted| over the fit points, µs.
    pub mean_abs_residual_us: f64,
    /// Worst |observed − calibrated-predicted| over the fit points, µs.
    pub max_abs_residual_us: f64,
}

/// One observed-vs-predicted row of the residual report.
#[derive(Debug, Clone)]
pub struct LayerResidual {
    /// Layer name.
    pub layer: String,
    /// Algorithm observed, precision-suffixed when the layer served
    /// quantized ("im2col-int8").
    pub algo: String,
    /// Observed steady-state latency (profile minimum), µs.
    pub observed_us: f64,
    /// Raw analytic prediction, µs.
    pub predicted_raw_us: f64,
    /// Prediction after applying the fitted calibration, µs.
    pub predicted_cal_us: f64,
}

/// The calibration result: effective device + fitted per-family
/// corrections + the residual evidence behind them.
#[derive(Debug, Clone)]
pub struct CalibratedDevice {
    /// Effective device: the base device with `ddr_gbps` divided by the
    /// global time-scale factor, so transition costs stay commensurate
    /// with the re-scaled compute costs.
    pub device: Device,
    /// Fitted per-family corrections; the fallback fit carries the
    /// global time-scale so unprofiled families are never accidentally
    /// priced at the raw analytic cost.
    pub calibration: DeviceCalibration,
    /// Global time-dilation factor (median of the per-family scales).
    pub global_scale: f64,
    /// Per-family fit summaries.
    pub fits: Vec<AlgoFitReport>,
    /// Per-layer observed-vs-predicted rows.
    pub residuals: Vec<LayerResidual>,
}

impl CalibratedDevice {
    /// ASCII residual report: per-family fits and per-layer
    /// observed-vs-predicted rows.
    pub fn report(&self) -> String {
        let mut fit_t = Table::new(
            &format!(
                "calibration fits (global time-scale {:.3}×, effective {:.1} MHz)",
                self.global_scale,
                self.device.freq_mhz / self.global_scale.max(1e-12)
            ),
            &["family", "points", "scale", "offset µs", "mean |resid| µs", "max |resid| µs"],
        );
        for f in &self.fits {
            fit_t.row(vec![
                f.family.clone(),
                f.points.to_string(),
                format!("{:.4}", f.scale),
                format!("{:.2}", f.offset_us),
                format!("{:.2}", f.mean_abs_residual_us),
                format!("{:.2}", f.max_abs_residual_us),
            ]);
        }
        let mut res_t = Table::new(
            "observed vs predicted",
            &["layer", "algo", "observed µs", "analytic µs", "calibrated µs"],
        );
        for r in &self.residuals {
            res_t.row(vec![
                r.layer.clone(),
                r.algo.clone(),
                format!("{:.2}", r.observed_us),
                format!("{:.2}", r.predicted_raw_us),
                format!("{:.2}", r.predicted_cal_us),
            ]);
        }
        format!("{}\n{}", fit_t.render(), res_t.render())
    }
}

/// Conv-equivalent spec of a layer the serving path times: conv layers
/// verbatim, FC layers as the 1×1 conv the native path executes.
pub(crate) fn conv_equivalent(cnn: &Cnn) -> BTreeMap<String, ConvSpec> {
    let mut specs = BTreeMap::new();
    for node in &cnn.nodes {
        match &node.op {
            Op::Conv(spec) => {
                specs.insert(node.name.clone(), spec.clone());
            }
            Op::Fc { c_in, c_out } => {
                specs.insert(
                    node.name.clone(),
                    ConvSpec::new(*c_in, *c_out, 1, 1, 1, 1, 1, 0, 0),
                );
            }
            _ => {}
        }
    }
    specs
}

/// Fit `y ≈ scale · x + offset` over `(analytic, observed)` second
/// pairs. OLS with intercept when there are enough spread-out points
/// for a stable one; through-origin otherwise. The returned fit always
/// has a strictly positive scale and a non-negative offset, so
/// calibrated costs remain valid PBQP node costs.
fn fit_family(points: &[(f64, f64)]) -> AlgoFit {
    let n = points.len() as f64;
    let sx: f64 = points.iter().map(|p| p.0).sum();
    let sy: f64 = points.iter().map(|p| p.1).sum();
    let sxx: f64 = points.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = points.iter().map(|p| p.0 * p.1).sum();
    let var = sxx - sx * sx / n.max(1.0);
    if points.len() >= 3 && var > 1e-24 {
        let scale = (sxy - sx * sy / n) / var;
        let offset = (sy - scale * sx) / n;
        if scale > 1e-12 && offset >= 0.0 {
            return AlgoFit { scale, offset_sec: offset };
        }
    }
    // through-origin fallback (also the path for negative intercepts:
    // a negative fitted offset means the intercept is not identifiable
    // from these points, not that the hardware pays negative overhead)
    let scale = if sxx > 1e-300 { (sxy / sxx).max(1e-12) } else { 1.0 };
    AlgoFit { scale, offset_sec: 0.0 }
}

/// Fit the device model to a profile snapshot.
///
/// `compiler` supplies the *base* analytic configuration (device,
/// Winograd tile, dataflow restrictions); any calibration it already
/// carries is deliberately ignored so repeated calibrations converge on
/// the analytic→observed fit instead of compounding. `(p1, p2)` is the
/// systolic-array shape of the plan the observations were served under.
/// Observations for layers the model does not contain are skipped.
pub fn calibrate(
    cnn: &Cnn,
    compiler: &Compiler,
    p1: usize,
    p2: usize,
    observations: &[LayerObs],
) -> Result<CalibratedDevice, DynamapError> {
    if p1 == 0 || p2 == 0 {
        return Err(DynamapError::Dse(format!(
            "calibration needs a valid array shape, got {p1}×{p2}"
        )));
    }
    let mut cm: CostModel = compiler.config().cost_model();
    cm.calibration = DeviceCalibration::identity();
    let specs = conv_equivalent(cnn);

    // (analytic sec, observed sec) per family + the residual rows
    let mut points: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
    let mut rows: Vec<(String, String, f64, f64)> = Vec::new();
    for obs in observations {
        if obs.count == 0 || !obs.min_us.is_finite() || obs.min_us < 0.0 {
            continue;
        }
        let Some(spec) = specs.get(&obs.layer) else { continue };
        // the observed label may carry a precision suffix
        // ("im2col-int8") when the layer served quantized; price the
        // analytic side at that same precision AND fit per
        // (family, precision) key — a host's int8 observed/analytic
        // ratio differs systematically from its f32 one (the int8
        // kernel's reductions vectorize, f32's cannot), so pooling the
        // two regimes would bias both fits. The cost model applies the
        // calibration under the same precision-suffixed key.
        let (family, precision) = crate::quant::parse_mapped(&obs.algo);
        let algo = resolve_algo(family, spec);
        if algo.family() != family {
            // the observation labels an algorithm this layer cannot run
            // (stale profile across a model change) — not evidence
            continue;
        }
        let predicted = cm.best_conv_cost_at(spec, algo, precision, p1, p2).seconds;
        if !(predicted > 0.0) {
            continue;
        }
        let observed = obs.min_us / 1e6;
        let key = crate::quant::mapped_name(family, precision);
        points.entry(key).or_default().push((predicted, observed));
        rows.push((obs.layer.clone(), obs.algo.clone(), predicted, observed));
    }
    if points.is_empty() {
        return Err(DynamapError::Dse(
            "calibration needs at least one profiled conv layer \
             (serve with profiling enabled first)"
                .into(),
        ));
    }

    let mut calibration = DeviceCalibration::identity();
    for (family, pts) in &points {
        calibration
            .per_algo
            .insert(family.clone(), fit_family(pts));
    }
    // global time-scale: median of the fitted per-family scales — the
    // fallback for unprofiled families and the factor the effective DDR
    // bandwidth dilates by
    let mut scales: Vec<f64> =
        calibration.per_algo.values().map(|f| f.scale).collect();
    scales.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let global_scale = scales[scales.len() / 2];
    calibration.fallback = AlgoFit { scale: global_scale, offset_sec: 0.0 };

    let mut device = compiler.config().device.clone();
    device.ddr_gbps = (device.ddr_gbps / global_scale.max(1e-12)).max(1e-9);

    // residual evidence under the fitted calibration
    let mut fits = Vec::new();
    for (family, pts) in &points {
        let fit = *calibration.fit(family);
        let resid: Vec<f64> =
            pts.iter().map(|(x, y)| (y - fit.apply(*x)).abs() * 1e6).collect();
        fits.push(AlgoFitReport {
            family: family.clone(),
            points: pts.len(),
            scale: fit.scale,
            offset_us: fit.offset_sec * 1e6,
            mean_abs_residual_us: resid.iter().sum::<f64>() / resid.len() as f64,
            max_abs_residual_us: resid.iter().cloned().fold(0.0, f64::max),
        });
    }
    let residuals = rows
        .into_iter()
        .map(|(layer, algo, pred, obs)| {
            // normalize the observed label into the canonical
            // (family, precision) fit key before applying
            let (family, precision) = crate::quant::parse_mapped(&algo);
            let key = crate::quant::mapped_name(family, precision);
            LayerResidual {
                predicted_cal_us: calibration.apply(&key, pred) * 1e6,
                layer,
                algo,
                observed_us: obs * 1e6,
                predicted_raw_us: pred * 1e6,
            }
        })
        .collect();

    Ok(CalibratedDevice { device, calibration, global_scale, fits, residuals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Device;
    use crate::graph::zoo;

    fn compiler() -> Compiler {
        Compiler::new().device(Device::small_edge())
    }

    fn synthetic_obs(
        cnn: &Cnn,
        compiler: &Compiler,
        p1: usize,
        p2: usize,
        factor: impl Fn(&str) -> f64,
    ) -> Vec<LayerObs> {
        let cm = compiler.config().cost_model();
        let specs = conv_equivalent(cnn);
        let mut obs = Vec::new();
        for (layer, spec) in &specs {
            for family in ["im2col", "kn2row", "winograd"] {
                let algo = resolve_algo(family, spec);
                if algo.family() != family {
                    continue; // family not executable on this layer
                }
                let us =
                    cm.best_conv_cost(spec, algo, p1, p2).seconds * 1e6 * factor(family);
                obs.push(LayerObs {
                    layer: layer.clone(),
                    algo: family.to_string(),
                    count: 8,
                    mean_us: us,
                    std_us: 0.0,
                    min_us: us,
                    max_us: us,
                });
            }
        }
        obs
    }

    #[test]
    fn recovers_a_known_per_family_skew() {
        let cnn = zoo::mini_inception();
        let c = compiler();
        let obs = synthetic_obs(&cnn, &c, 16, 16, |family| {
            if family == "kn2row" {
                50.0
            } else {
                1.0
            }
        });
        let cal = calibrate(&cnn, &c, 16, 16, &obs).unwrap();
        let kn = cal.calibration.fit("kn2row");
        let im = cal.calibration.fit("im2col");
        assert!((kn.apply(1.0) / 50.0 - 1.0).abs() < 0.05, "kn2row fit {kn:?}");
        assert!((im.apply(1.0) - 1.0).abs() < 0.05, "im2col fit {im:?}");
        assert!(
            cal.residuals.iter().all(|r| {
                (r.predicted_cal_us - r.observed_us).abs()
                    <= 0.05 * r.observed_us.max(1e-6)
            }),
            "exact synthetic observations must calibrate to near-zero residuals"
        );
        assert!(cal.report().contains("kn2row"));
    }

    #[test]
    fn unprofiled_family_inherits_the_global_scale() {
        let cnn = zoo::mini_inception();
        let c = compiler();
        // observe only im2col, uniformly 10× slower than analytic
        let obs: Vec<LayerObs> = synthetic_obs(&cnn, &c, 16, 16, |_| 10.0)
            .into_iter()
            .filter(|o| o.algo == "im2col")
            .collect();
        let cal = calibrate(&cnn, &c, 16, 16, &obs).unwrap();
        assert!((cal.global_scale / 10.0 - 1.0).abs() < 0.05);
        // winograd was never observed: it must be priced at the global
        // time-scale, not at the raw analytic cost
        assert!((cal.calibration.fit("winograd").scale / 10.0 - 1.0).abs() < 0.05);
        // effective DDR bandwidth dilates by the same factor
        let base = c.config().device.ddr_gbps;
        assert!((cal.device.ddr_gbps * 10.0 / base - 1.0).abs() < 0.05);
    }

    #[test]
    fn empty_profile_is_a_typed_error() {
        let cnn = zoo::mini_inception();
        let e = calibrate(&cnn, &compiler(), 16, 16, &[]).unwrap_err();
        assert!(matches!(e, DynamapError::Dse(_)), "{e}");
    }

    #[test]
    fn affine_fit_recovers_scale_and_offset() {
        // y = 3x + 0.5 over well-spread points
        let pts: Vec<(f64, f64)> =
            (1..=6).map(|i| (i as f64, 3.0 * i as f64 + 0.5)).collect();
        let f = fit_family(&pts);
        assert!((f.scale - 3.0).abs() < 1e-9);
        assert!((f.offset_sec - 0.5).abs() < 1e-9);
        // two points: through-origin fallback, still positive
        let f = fit_family(&[(1.0, 2.0), (2.0, 4.0)]);
        assert!((f.scale - 2.0).abs() < 1e-9);
        assert_eq!(f.offset_sec, 0.0);
    }
}
