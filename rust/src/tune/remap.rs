//! Re-solve the DSE under a calibrated cost model and hot-swap the
//! improved plan into the live serving engine.
//!
//! [`remap`] re-runs the full mapping flow (`CostGraph::build` + the
//! series-parallel PBQP solve, via
//! [`Compiler::compile`](crate::api::Compiler::compile)) with the
//! [`CalibratedDevice`] produced by [`super::calibrate::calibrate`],
//! diffs the
//! resulting per-layer algorithm map against the plan currently being
//! served, and — when the predicted end-to-end latency improves beyond
//! a hysteresis threshold — builds a freshly prepared
//! [`NativeState`](crate::api::NativeState) for the new map and swaps
//! it into the model's [`crate::serve::StateCell`]. The swap is an
//! `Arc` epoch swap: batches already in flight finish on the plan they
//! started with, later batches pick up the new one, and no request is
//! ever lost, duplicated or served by a half-updated plan.

use std::collections::BTreeMap;

use crate::api::session::resolve_algo;
use crate::api::{Backend, Compiler, DynamapError, PlanArtifact, Session};
use crate::cost::conv::CostModel;
use crate::graph::{zoo, Cnn};
use crate::serve::ModelRegistry;

use super::calibrate::{conv_equivalent, CalibratedDevice};

/// When [`remap`] actually swaps.
#[derive(Debug, Clone)]
pub struct RemapConfig {
    /// Minimum predicted end-to-end improvement required to swap, as a
    /// fraction (0.05 = swap only when the new plan is predicted ≥5%
    /// faster). Hysteresis keeps borderline re-fits from flapping the
    /// plan back and forth under measurement noise.
    pub hysteresis: f64,
}

impl Default for RemapConfig {
    fn default() -> RemapConfig {
        RemapConfig { hysteresis: 0.05 }
    }
}

/// One layer whose (algorithm, precision) assignment changed.
#[derive(Debug, Clone)]
pub struct AlgoChange {
    /// Layer name.
    pub layer: String,
    /// Assignment served before the remap (family name, precision
    /// suffixed when quantized — e.g. "im2col-int8").
    pub from: String,
    /// Assignment the calibrated plan chooses, same spelling.
    pub to: String,
}

/// What one [`remap`] call decided and did.
#[derive(Debug, Clone)]
pub struct RemapOutcome {
    /// Canonical model name.
    pub model: String,
    /// Whether a new plan was swapped into the registry.
    pub swapped: bool,
    /// The swap epoch after the swap (`None` when no swap happened).
    pub epoch: Option<u64>,
    /// Array shape of the calibrated plan.
    pub shape: (usize, usize),
    /// Layers whose algorithm assignment changed.
    pub changed: Vec<AlgoChange>,
    /// Predicted end-to-end compute of the *served* map under the
    /// calibrated model, µs.
    pub predicted_before_us: f64,
    /// Predicted end-to-end compute of the calibrated plan's map, µs.
    pub predicted_after_us: f64,
    /// `predicted_before_us / predicted_after_us`.
    pub predicted_speedup: f64,
}

impl RemapOutcome {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        if self.swapped {
            format!(
                "{}: swapped plan (epoch {}, {} layer(s) changed, predicted \
                 {:.0}µs → {:.0}µs, {:.2}x)",
                self.model,
                self.epoch.unwrap_or(0),
                self.changed.len(),
                self.predicted_before_us,
                self.predicted_after_us,
                self.predicted_speedup
            )
        } else if self.changed.is_empty() {
            format!(
                "{}: kept plan (calibrated re-solve agrees with the served mapping)",
                self.model
            )
        } else {
            format!(
                "{}: kept plan ({} layer(s) would change but predicted gain \
                 {:.2}x is inside the hysteresis band)",
                self.model,
                self.changed.len(),
                self.predicted_speedup
            )
        }
    }
}

/// Predicted end-to-end conv/FC compute (µs) of serving `map` on a
/// `p1 × p2` array under `cm` — the quantity the hysteresis decision
/// compares. Transitions are excluded: the native serving path the
/// observations come from has no DRAM layout round-trips between
/// layers.
pub fn predicted_compute_us(
    cnn: &Cnn,
    cm: &CostModel,
    p1: usize,
    p2: usize,
    map: &BTreeMap<String, String>,
) -> f64 {
    let mut total = 0.0;
    for (layer, spec) in conv_equivalent(cnn) {
        let served = map.get(&layer).map(String::as_str).unwrap_or("im2col");
        let (family, precision) = crate::quant::parse_mapped(served);
        let algo = resolve_algo(family, &spec);
        total += cm.best_conv_cost_at(&spec, algo, precision, p1, p2).seconds;
    }
    total * 1e6
}

/// The registry-independent core of the remap decision: what a
/// calibrated plan changes relative to a served map, and by how much.
/// Shared by [`remap`] (live hot-swap) and `dynamap tune` (offline
/// replay), so the two can never disagree about whether a profile
/// justifies a swap.
#[derive(Debug, Clone)]
pub struct PlanDelta {
    /// `P_SA1 × P_SA2` shape of the calibrated plan.
    pub shape: (usize, usize),
    /// The served map with the calibrated plan's conv assignments
    /// overlaid (non-conv entries, e.g. FC layers, carry over).
    pub new_map: BTreeMap<String, String>,
    /// Layers whose algorithm assignment changed.
    pub changed: Vec<AlgoChange>,
    /// Predicted end-to-end compute of the *base* map under the
    /// calibrated model, µs.
    pub predicted_before_us: f64,
    /// Predicted end-to-end compute of the calibrated plan's map, µs.
    pub predicted_after_us: f64,
    /// `predicted_before_us / predicted_after_us`.
    pub predicted_speedup: f64,
}

impl PlanDelta {
    /// The swap decision: at least one layer changes AND the predicted
    /// gain clears the hysteresis band.
    pub fn improves(&self, hysteresis: f64) -> bool {
        !self.changed.is_empty()
            && self.predicted_after_us
                <= self.predicted_before_us * (1.0 - hysteresis)
    }
}

/// Diff a calibrated plan `artifact` (compiled by `compiler`, which
/// carries the calibration) against the `base_map` currently served,
/// pricing both sides with the same calibrated cost model at the
/// plan's array shape.
pub fn plan_delta(
    cnn: &Cnn,
    compiler: &Compiler,
    artifact: &PlanArtifact,
    base_map: &BTreeMap<String, String>,
) -> PlanDelta {
    let (p1, p2) = (artifact.plan.p1, artifact.plan.p2);
    let mut new_map = base_map.clone();
    for layer in &artifact.plan.mapping.layers {
        // spell (family, precision) the serving-layer way so a
        // precision flip (e.g. "im2col" → "im2col-int8") registers as
        // a change exactly like an algorithm flip does
        new_map.insert(
            layer.name.clone(),
            crate::quant::mapped_name(layer.cost.algo.family(), layer.cost.precision),
        );
    }
    let changed: Vec<AlgoChange> = base_map
        .iter()
        .filter_map(|(layer, from)| {
            let to = new_map.get(layer)?;
            (to != from).then(|| AlgoChange {
                layer: layer.clone(),
                from: from.clone(),
                to: to.clone(),
            })
        })
        .collect();
    let cm = compiler.config().cost_model();
    let before = predicted_compute_us(cnn, &cm, p1, p2, base_map);
    let after = predicted_compute_us(cnn, &cm, p1, p2, &new_map);
    PlanDelta {
        shape: (p1, p2),
        new_map,
        changed,
        predicted_before_us: before,
        predicted_after_us: after,
        predicted_speedup: if after > 0.0 { before / after } else { 1.0 },
    }
}

/// Calibrated re-solve + diff + (hysteresis-gated) hot swap for one
/// hosted model. See the module docs for the swap safety argument.
pub fn remap(
    registry: &ModelRegistry,
    model: &str,
    cal: &CalibratedDevice,
    config: &RemapConfig,
) -> Result<RemapOutcome, DynamapError> {
    let canonical = zoo::canonical_name(model)
        .ok_or_else(|| DynamapError::UnknownModel(model.to_string()))?;
    // peek, not host: re-mapping must neither resurrect an evicted
    // model nor refresh LRU recency — only real traffic does that
    let host = registry.peek(canonical).ok_or_else(|| {
        DynamapError::Serve(format!(
            "cannot remap '{canonical}': model is not resident (host it first)"
        ))
    })?;
    let state = host.state();
    let cnn = state.cnn().clone();
    let old_map = state.algo_map().clone();

    // re-run the full mapping flow in observed time units
    let compiler = registry
        .config()
        .compiler
        .clone()
        .device(cal.device.clone())
        .calibration(cal.calibration.clone());
    let artifact = compiler.compile(&cnn)?;
    let delta = plan_delta(&cnn, &compiler, &artifact, &old_map);
    let improves = delta.improves(config.hysteresis);

    let PlanDelta {
        shape,
        new_map,
        changed,
        predicted_before_us,
        predicted_after_us,
        predicted_speedup,
    } = delta;
    let mut outcome = RemapOutcome {
        model: canonical.to_string(),
        swapped: false,
        epoch: None,
        shape,
        changed,
        predicted_before_us,
        predicted_after_us,
        predicted_speedup,
    };
    if !improves {
        return Ok(outcome);
    }

    // prepare the new serving state from the same artifacts: only the
    // algorithm map changes, so this is a weight re-lowering, not a DSE
    let dir = registry.config().artifacts_root.join(canonical);
    let mut builder = Session::builder(dir.to_string_lossy().into_owned())
        .backend(Backend::Native)
        .algo_map(new_map);
    if let Some(profile) = host.profile() {
        // keep observing under the new plan so later passes can refine
        builder = builder.profiler(profile.clone());
    }
    let session = builder.build()?;
    let new_state = session.native_state().ok_or_else(|| {
        DynamapError::Serve("remap: native session produced no shareable state".into())
    })?;
    let epoch = registry.swap_state(canonical, new_state, Some(shape))?;
    outcome.swapped = true;
    outcome.epoch = Some(epoch);
    Ok(outcome)
}
