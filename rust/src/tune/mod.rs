//! Online adaptation: profile → calibrate → remap → hot-swap.
//!
//! DYNAMAP picks per-layer algorithms with an analytic cost model
//! (Eq. 9–14). Analytic DSE models drift from reality (fpgaConvNet,
//! arxiv 1711.08740, calibrates its models against measured
//! performance for exactly this reason), and serving conditions change
//! while a process is live (the multi-CNN regime of f-CNNx, arxiv
//! 1805.10174). This module closes the loop so the "dynamic" in
//! DYNAMAP extends past compile time:
//!
//! * [`profiler`] — [`LayerProfile`]: bounded, lock-cheap per-layer ×
//!   per-algorithm wall-clock observations recorded by the native
//!   serving path itself
//!   ([`NativeState::profiled`](crate::api::NativeState::profiled)).
//! * [`calibrate`](mod@calibrate) — least-squares fit of the effective
//!   [`Device`](crate::cost::Device) parameters (achievable per-family
//!   GEMM throughput, effective DDR bandwidth, per-algorithm overhead
//!   constants) from a profile, producing a [`CalibratedDevice`] with
//!   an observed-vs-predicted residual report.
//! * [`remap`](mod@remap) — re-runs cost-graph construction + the
//!   series-parallel PBQP solve under the calibrated model, diffs the
//!   mapping against the live plan and, past a hysteresis threshold,
//!   atomically hot-swaps a freshly prepared serving state into the
//!   [`crate::serve::ModelRegistry`] (epoch/`Arc` swap — in-flight
//!   batches finish on the old plan; no request is lost or duplicated).
//! * [`controller`] — the background cadence thread behind
//!   `dynamap serve --tune` (every N requests or T seconds, knobs via
//!   [`TuneConfig`] / `DYNAMAP_TUNE*` env vars).
//! * [`report`] — the observed-vs-predicted table the `serve` REPL
//!   prints on `stats`.
//! * [`cli`] — `dynamap tune`, the one-shot offline calibrate + re-map
//!   over a recorded profile.
//!
//! ```no_run
//! use dynamap::serve::{ModelRegistry, RegistryConfig};
//! use dynamap::tune::{TuneConfig, TuneController};
//! use std::sync::Arc;
//!
//! let mut config = RegistryConfig::default();
//! config.profile = true; // attach a LayerProfile to every host
//! let registry = Arc::new(ModelRegistry::new(config));
//! let controller = TuneController::spawn(registry.clone(), TuneConfig::default());
//! // ... serve traffic; the controller re-maps in the background ...
//! controller.shutdown();
//! ```
#![warn(missing_docs)]
#![deny(clippy::correctness, clippy::suspicious)]

pub mod calibrate;
pub mod cli;
pub mod controller;
pub mod profiler;
pub mod remap;
pub mod report;

pub use calibrate::{calibrate, AlgoFitReport, CalibratedDevice, LayerResidual};
pub use controller::{run_pass, TuneConfig, TuneController};
pub use profiler::{LayerObs, LayerProfile};
pub use remap::{
    plan_delta, predicted_compute_us, remap, AlgoChange, PlanDelta, RemapConfig,
    RemapOutcome,
};
pub use report::observed_vs_predicted;
