//! [`TuneController`] — the background profile → calibrate → remap
//! cadence.
//!
//! One thread per registry wakes every [`TuneConfig::interval`] and,
//! for each resident model whose profile has accumulated at least
//! [`TuneConfig::min_new_requests`] new requests since its last tune
//! attempt, runs [`calibrate`](super::calibrate::calibrate) +
//! [`remap`](super::remap::remap). Models hosted without profiling,
//! models without enough fresh evidence and models whose calibrated
//! re-solve does not clear the hysteresis band are all skipped, so a
//! converged server settles into cheap no-op ticks. Swap counts surface
//! through [`crate::serve::ServerMetrics`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Duration;

use crate::serve::ModelRegistry;

use super::calibrate::calibrate;
use super::remap::{remap, RemapConfig, RemapOutcome};

/// Cadence and thresholds for the adaptation loop.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// How often the controller wakes to consider a pass.
    pub interval: Duration,
    /// Minimum profiled requests per model between tune attempts (the
    /// "every N requests" half of the cadence).
    pub min_new_requests: u64,
    /// Hysteresis handed to [`remap`] (minimum predicted improvement).
    pub hysteresis: f64,
    /// Print a line per remap outcome (the `serve --tune` REPL does).
    pub verbose: bool,
}

impl Default for TuneConfig {
    fn default() -> TuneConfig {
        TuneConfig {
            interval: Duration::from_secs(5),
            min_new_requests: 64,
            hysteresis: 0.05,
            verbose: false,
        }
    }
}

impl TuneConfig {
    /// Read the loop configuration from the environment: `DYNAMAP_TUNE`
    /// (`1`/`true`/`on`) enables it, with the cadence knobs of
    /// [`TuneConfig::knobs_from_env`] applied. Returns `None` when
    /// tuning is not enabled.
    pub fn from_env() -> Option<TuneConfig> {
        let on = std::env::var("DYNAMAP_TUNE")
            .map(|v| matches!(v.to_ascii_lowercase().as_str(), "1" | "true" | "on"))
            .unwrap_or(false);
        on.then(TuneConfig::knobs_from_env)
    }

    /// Read only the cadence knobs — `DYNAMAP_TUNE_INTERVAL_MS`,
    /// `DYNAMAP_TUNE_MIN_REQUESTS`, `DYNAMAP_TUNE_HYSTERESIS` — over
    /// the defaults, without requiring the `DYNAMAP_TUNE` enable flag.
    /// Callers that opted in by other means (`serve --tune`) use this,
    /// so the knobs are never silently discarded.
    pub fn knobs_from_env() -> TuneConfig {
        let mut config = TuneConfig::default();
        if let Ok(ms) = std::env::var("DYNAMAP_TUNE_INTERVAL_MS") {
            if let Ok(ms) = ms.parse::<u64>() {
                config.interval = Duration::from_millis(ms.max(1));
            }
        }
        if let Ok(n) = std::env::var("DYNAMAP_TUNE_MIN_REQUESTS") {
            if let Ok(n) = n.parse::<u64>() {
                config.min_new_requests = n;
            }
        }
        if let Ok(h) = std::env::var("DYNAMAP_TUNE_HYSTERESIS") {
            if let Ok(h) = h.parse::<f64>() {
                config.hysteresis = h.clamp(0.0, 0.9);
            }
        }
        config
    }
}

/// One profile → calibrate → remap sweep over the registry's resident
/// models. `seen` carries each model's request count at its last
/// attempt (the controller owns one across ticks; one-shot callers
/// pass a fresh map). Models that error during calibration (e.g. not
/// enough evidence yet) are skipped, not fatal.
pub fn run_pass(
    registry: &ModelRegistry,
    config: &TuneConfig,
    seen: &mut BTreeMap<String, u64>,
) -> Vec<RemapOutcome> {
    let mut outcomes = Vec::new();
    for model in registry.resident() {
        // peek, not host: a background tick must neither refresh LRU
        // recency (idle models would dodge eviction) nor re-host
        let Some(host) = registry.peek(&model) else { continue };
        let Some(profile) = host.profile() else { continue };
        let requests = profile.requests();
        let mut last = seen.get(&model).copied().unwrap_or(0);
        if requests < last {
            // the profile's counter went backwards: the model was
            // evicted and re-hosted with a fresh LayerProfile. Reset
            // the high-water mark or the loop would stay dead until
            // the new profile re-accumulates the old lifetime count.
            seen.insert(model.clone(), 0);
            last = 0;
        }
        if requests < last + config.min_new_requests {
            continue;
        }
        let state = host.state();
        let Some((p1, p2)) = host.plan_shape() else { continue };
        let snapshot = profile.snapshot();
        let cal = match calibrate(state.cnn(), &registry.config().compiler, p1, p2, &snapshot)
        {
            Ok(cal) => cal,
            Err(e) => {
                if config.verbose {
                    eprintln!("[tune] {model}: calibration skipped: {e}");
                }
                continue;
            }
        };
        seen.insert(model.clone(), requests);
        match remap(registry, &model, &cal, &RemapConfig { hysteresis: config.hysteresis })
        {
            Ok(outcome) => outcomes.push(outcome),
            Err(e) => {
                // not fatal for the loop, but never invisible: without
                // this line an operator cannot tell "converged" from
                // "remap broken" (both show zero swaps)
                eprintln!("[tune] {model}: remap failed: {e}");
                continue;
            }
        }
    }
    outcomes
}

/// The background adaptation thread. Spawn with
/// [`TuneController::spawn`], stop with [`TuneController::shutdown`]
/// (also runs on drop). The thread holds an `Arc<ModelRegistry>`, so
/// the registry outlives the controller wherever it is stopped.
pub struct TuneController {
    stop: Mutex<Option<mpsc::Sender<()>>>,
    handle: Mutex<Option<thread::JoinHandle<()>>>,
    passes: Arc<AtomicU64>,
    swaps: Arc<AtomicU64>,
}

impl TuneController {
    /// Start the cadence thread over `registry`.
    pub fn spawn(registry: Arc<ModelRegistry>, config: TuneConfig) -> TuneController {
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        let passes = Arc::new(AtomicU64::new(0));
        let swaps = Arc::new(AtomicU64::new(0));
        let (passes_t, swaps_t) = (passes.clone(), swaps.clone());
        let handle = thread::Builder::new()
            .name("dynamap-tune".into())
            .spawn(move || {
                let mut seen = BTreeMap::new();
                loop {
                    match stop_rx.recv_timeout(config.interval) {
                        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                    }
                    let outcomes = run_pass(&registry, &config, &mut seen);
                    passes_t.fetch_add(1, Ordering::Relaxed);
                    for outcome in outcomes {
                        if outcome.swapped {
                            swaps_t.fetch_add(1, Ordering::Relaxed);
                        }
                        if config.verbose {
                            println!("[tune] {}", outcome.summary());
                        }
                    }
                }
            })
            .expect("spawn tune controller thread");
        TuneController {
            stop: Mutex::new(Some(stop_tx)),
            handle: Mutex::new(Some(handle)),
            passes,
            swaps,
        }
    }

    /// Completed cadence passes so far.
    pub fn passes(&self) -> u64 {
        self.passes.load(Ordering::Relaxed)
    }

    /// Plan swaps performed by this controller so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }

    /// Stop the cadence thread and join it. Idempotent.
    pub fn shutdown(&self) {
        let stop = self.stop.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(tx) = stop {
            let _ = tx.send(());
        }
        let handle = self.handle.lock().unwrap_or_else(|p| p.into_inner()).take();
        if let Some(handle) = handle {
            let _ = handle.join();
        }
    }
}

impl Drop for TuneController {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_env_parses_and_defaults() {
        std::env::remove_var("DYNAMAP_TUNE");
        std::env::set_var("DYNAMAP_TUNE_INTERVAL_MS", "250");
        std::env::set_var("DYNAMAP_TUNE_MIN_REQUESTS", "7");
        std::env::set_var("DYNAMAP_TUNE_HYSTERESIS", "0.2");
        // enable flag absent: from_env is None, but callers that opted
        // in by other means still see the knobs
        assert!(TuneConfig::from_env().is_none());
        let knobs = TuneConfig::knobs_from_env();
        assert_eq!(knobs.interval, Duration::from_millis(250));
        assert_eq!(knobs.min_new_requests, 7);
        std::env::set_var("DYNAMAP_TUNE", "1");
        let config = TuneConfig::from_env().expect("enabled");
        assert_eq!(config.interval, Duration::from_millis(250));
        assert_eq!(config.min_new_requests, 7);
        assert!((config.hysteresis - 0.2).abs() < 1e-12);
        std::env::remove_var("DYNAMAP_TUNE");
        std::env::remove_var("DYNAMAP_TUNE_INTERVAL_MS");
        std::env::remove_var("DYNAMAP_TUNE_MIN_REQUESTS");
        std::env::remove_var("DYNAMAP_TUNE_HYSTERESIS");
    }
}
