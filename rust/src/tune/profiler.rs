//! [`LayerProfile`] — per-layer, per-algorithm latency observations
//! from the live native serving path.
//!
//! Every request served by a profiled
//! [`NativeState`](crate::api::NativeState) records one wall-clock
//! sample per conv/FC layer under its currently served algorithm. The
//! store keeps streaming statistics (Welford mean/variance plus
//! min/max) per `(layer, algorithm)` key — O(1) memory per key, and the
//! key space is bounded by `layers × algorithm families`, so the
//! profile never grows with traffic. Recording takes one short mutex
//! acquisition per *request* (not per layer), keeping the cost on the
//! serving hot path negligible next to the convolutions themselves.
//!
//! Snapshots feed [`crate::tune::calibrate::calibrate`]; profiles
//! round-trip through JSON (`save`/`load`) so `dynamap tune` can
//! replay a profile recorded by a `dynamap serve --tune` process.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::api::DynamapError;
use crate::util::json::Json;

/// Streaming per-key statistics (Welford's online algorithm).
#[derive(Debug, Clone, Default)]
struct Stat {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stat {
    fn push(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
    }

    fn std(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            (self.m2 / self.count as f64).sqrt()
        }
    }
}

/// One `(layer, algorithm)` observation in a profile snapshot. All
/// latencies are microseconds of wall-clock on the native kernel path.
#[derive(Debug, Clone)]
pub struct LayerObs {
    /// Layer name (manifest / CNN node name).
    pub layer: String,
    /// Algorithm family the layer was served with ("im2col", "kn2row",
    /// "winograd").
    pub algo: String,
    /// Number of samples behind the statistics.
    pub count: u64,
    /// Mean observed latency, µs.
    pub mean_us: f64,
    /// Population standard deviation, µs.
    pub std_us: f64,
    /// Fastest observed sample, µs — the steady-state estimate
    /// calibration fits against (robust to scheduler noise).
    pub min_us: f64,
    /// Slowest observed sample, µs.
    pub max_us: f64,
}

/// Bounded, lock-cheap store of per-layer latency observations for one
/// model. Shared (`Arc`) between the serving path (writer) and the tune
/// controller / REPL reporting (readers); every method takes `&self`.
#[derive(Debug)]
pub struct LayerProfile {
    model: String,
    inner: Mutex<BTreeMap<(String, String), Stat>>,
    requests: AtomicU64,
}

impl LayerProfile {
    /// An empty profile for `model`.
    pub fn new(model: impl Into<String>) -> LayerProfile {
        LayerProfile {
            model: model.into(),
            inner: Mutex::new(BTreeMap::new()),
            requests: AtomicU64::new(0),
        }
    }

    /// Model this profile observes.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Record one request's per-layer samples: `(layer, algorithm,
    /// µs)` triples, exactly the shape of
    /// [`crate::api::InferMetrics::per_layer_us`]. One lock
    /// acquisition for the whole request.
    pub fn record(&self, per_layer_us: &[(String, String, f64)]) {
        if per_layer_us.is_empty() {
            return;
        }
        {
            let mut inner = self.lock();
            for (layer, algo, us) in per_layer_us {
                if !us.is_finite() {
                    continue;
                }
                inner
                    .entry((layer.clone(), algo.clone()))
                    .or_default()
                    .push(*us);
            }
        }
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// How many requests have been recorded (the tune controller's
    /// cadence counter — an atomic read, no lock).
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Number of distinct `(layer, algorithm)` keys observed so far.
    pub fn len(&self) -> usize {
        self.lock().len()
    }

    /// `true` when nothing has been recorded yet.
    pub fn is_empty(&self) -> bool {
        self.lock().is_empty()
    }

    /// Point-in-time copy of every observation, sorted by
    /// `(layer, algorithm)`.
    pub fn snapshot(&self) -> Vec<LayerObs> {
        self.lock()
            .iter()
            .map(|((layer, algo), s)| LayerObs {
                layer: layer.clone(),
                algo: algo.clone(),
                count: s.count,
                mean_us: s.mean,
                std_us: s.std(),
                min_us: s.min,
                max_us: s.max,
            })
            .collect()
    }

    /// Drop every observation (the request counter keeps counting).
    pub fn clear(&self) {
        self.lock().clear();
    }

    /// Serialize the profile (model + per-key statistics).
    pub fn to_json(&self) -> Json {
        let layers = self
            .snapshot()
            .into_iter()
            .map(|o| {
                Json::obj(vec![
                    ("layer", Json::str(o.layer)),
                    ("algo", Json::str(o.algo)),
                    ("count", Json::num(o.count as f64)),
                    ("mean_us", Json::num(o.mean_us)),
                    ("std_us", Json::num(o.std_us)),
                    ("min_us", Json::num(o.min_us)),
                    ("max_us", Json::num(o.max_us)),
                ])
            })
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("model", Json::str(self.model.clone())),
            ("requests", Json::num(self.requests() as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }

    /// Rebuild a profile from its serialized form.
    pub fn from_json(j: &Json) -> Result<LayerProfile, DynamapError> {
        let model = j
            .get("model")
            .as_str()
            .ok_or_else(|| DynamapError::Artifact("profile: missing 'model'".into()))?
            .to_string();
        let profile = LayerProfile::new(model);
        let layers = j
            .get("layers")
            .as_arr()
            .ok_or_else(|| DynamapError::Artifact("profile: missing 'layers'".into()))?;
        {
            let mut inner = profile.lock();
            for l in layers {
                let field = |k: &str| -> Result<f64, DynamapError> {
                    l.get(k).as_f64().ok_or_else(|| {
                        DynamapError::Artifact(format!("profile layer: missing '{k}'"))
                    })
                };
                let layer = l.get("layer").as_str().ok_or_else(|| {
                    DynamapError::Artifact("profile layer: missing 'layer'".into())
                })?;
                let algo = l.get("algo").as_str().ok_or_else(|| {
                    DynamapError::Artifact("profile layer: missing 'algo'".into())
                })?;
                let count = field("count")? as u64;
                let mean = field("mean_us")?;
                let std = field("std_us")?;
                inner.insert(
                    (layer.to_string(), algo.to_string()),
                    Stat {
                        count,
                        mean,
                        m2: std * std * count as f64,
                        min: field("min_us")?,
                        max: field("max_us")?,
                    },
                );
            }
        }
        profile
            .requests
            .store(j.get("requests").as_u64().unwrap_or(0), Ordering::Relaxed);
        Ok(profile)
    }

    /// Write the profile as pretty JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), DynamapError> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| DynamapError::io(parent, e))?;
            }
        }
        std::fs::write(path, self.to_json().pretty()).map_err(|e| DynamapError::io(path, e))
    }

    /// Load a profile previously written by [`LayerProfile::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<LayerProfile, DynamapError> {
        let path = path.as_ref();
        let text =
            std::fs::read_to_string(path).map_err(|e| DynamapError::io(path, e))?;
        let j = Json::parse(&text).map_err(|e| DynamapError::json_in(path, e))?;
        LayerProfile::from_json(&j)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, BTreeMap<(String, String), Stat>> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_statistics_are_exact() {
        let p = LayerProfile::new("m");
        for us in [10.0, 20.0, 30.0] {
            p.record(&[("c1".into(), "im2col".into(), us)]);
        }
        assert_eq!(p.requests(), 3);
        let snap = p.snapshot();
        assert_eq!(snap.len(), 1);
        let o = &snap[0];
        assert_eq!((o.layer.as_str(), o.algo.as_str(), o.count), ("c1", "im2col", 3));
        assert!((o.mean_us - 20.0).abs() < 1e-12);
        assert_eq!((o.min_us, o.max_us), (10.0, 30.0));
        // population std of {10,20,30} = sqrt(200/3)
        assert!((o.std_us - (200.0f64 / 3.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn keys_stay_bounded_and_separate_algorithms() {
        let p = LayerProfile::new("m");
        for i in 0..1000 {
            p.record(&[
                ("c1".into(), "im2col".into(), i as f64),
                ("c1".into(), "kn2row".into(), i as f64 + 1.0),
            ]);
        }
        assert_eq!(p.len(), 2, "one key per (layer, algo), not per sample");
        assert_eq!(p.requests(), 1000);
        p.clear();
        assert!(p.is_empty());
    }

    #[test]
    fn json_round_trip_preserves_statistics() {
        let p = LayerProfile::new("mini-inception");
        for us in [5.0, 7.0, 9.0, 11.0] {
            p.record(&[
                ("stem".into(), "winograd".into(), us),
                ("head".into(), "im2col".into(), us * 2.0),
            ]);
        }
        let back = LayerProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back.model(), "mini-inception");
        assert_eq!(back.requests(), 4);
        let (a, b) = (p.snapshot(), back.snapshot());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((&x.layer, &x.algo, x.count), (&y.layer, &y.algo, y.count));
            assert!((x.mean_us - y.mean_us).abs() < 1e-9);
            assert!((x.std_us - y.std_us).abs() < 1e-6);
            assert_eq!((x.min_us, x.max_us), (y.min_us, y.max_us));
        }
    }

    #[test]
    fn save_load_round_trip() {
        let p = LayerProfile::new("m");
        p.record(&[("c".into(), "im2col".into(), 42.0)]);
        let path = std::env::temp_dir()
            .join(format!("dynamap_profile_{}.json", std::process::id()));
        p.save(&path).unwrap();
        let back = LayerProfile::load(&path).unwrap();
        assert_eq!(back.snapshot()[0].mean_us, 42.0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn malformed_json_is_a_typed_error() {
        let j = Json::parse(r#"{"layers": []}"#).unwrap();
        assert!(matches!(
            LayerProfile::from_json(&j),
            Err(DynamapError::Artifact(_))
        ));
    }
}
