//! Observed-vs-predicted reporting — calibration quality at a glance.
//!
//! [`observed_vs_predicted`] renders one [`crate::util::table::Table`]
//! row per served conv/FC layer: the analytic prediction for the
//! algorithm currently being served next to the profiled wall-clock
//! observations. The `dynamap serve` REPL prints it on `stats` so
//! calibration quality is inspectable on a live server without a bench
//! run; `dynamap tune` prints it when replaying a recorded profile.

use std::collections::BTreeMap;

use crate::api::session::resolve_algo;
use crate::api::Compiler;
use crate::cost::DeviceCalibration;
use crate::graph::Cnn;
use crate::util::table::Table;

use super::calibrate::conv_equivalent;
use super::profiler::LayerObs;

/// Per-layer observed-vs-predicted table for the algorithms in
/// `algo_map`, priced by `compiler`'s *base* (uncalibrated) model on a
/// `p1 × p2` array. Layers without observations render `-` columns, so
/// the table doubles as a coverage check for the profiler.
pub fn observed_vs_predicted(
    cnn: &Cnn,
    compiler: &Compiler,
    p1: usize,
    p2: usize,
    algo_map: &BTreeMap<String, String>,
    observations: &[LayerObs],
) -> Table {
    let mut cm = compiler.config().cost_model();
    cm.calibration = DeviceCalibration::identity();
    let by_key: BTreeMap<(&str, &str), &LayerObs> = observations
        .iter()
        .map(|o| ((o.layer.as_str(), o.algo.as_str()), o))
        .collect();
    let mut t = Table::new(
        &format!("observed vs predicted per-layer cycles ({})", cnn.name),
        &[
            "layer", "algo", "pred µs", "pred cycles", "obs min µs", "obs mean µs",
            "samples", "obs/pred",
        ],
    );
    for (layer, spec) in conv_equivalent(cnn) {
        let served = algo_map.get(&layer).map(String::as_str).unwrap_or("im2col");
        let (family, precision) = crate::quant::parse_mapped(served);
        let algo = resolve_algo(family, &spec);
        let cost = cm.best_conv_cost_at(&spec, algo, precision, p1, p2);
        let pred_us = cost.seconds * 1e6;
        match by_key.get(&(layer.as_str(), served)) {
            Some(o) => {
                let ratio = if pred_us > 0.0 { o.min_us / pred_us } else { 0.0 };
                t.row(vec![
                    layer.clone(),
                    served.to_string(),
                    format!("{pred_us:.2}"),
                    cost.cycles.to_string(),
                    format!("{:.2}", o.min_us),
                    format!("{:.2}", o.mean_us),
                    o.count.to_string(),
                    format!("{ratio:.2}"),
                ]);
            }
            None => {
                t.row(vec![
                    layer.clone(),
                    served.to_string(),
                    format!("{pred_us:.2}"),
                    cost.cycles.to_string(),
                    "-".into(),
                    "-".into(),
                    "0".into(),
                    "-".into(),
                ]);
            }
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::Device;
    use crate::graph::zoo;

    #[test]
    fn table_covers_every_served_layer() {
        let cnn = zoo::mini_inception();
        let compiler = Compiler::new().device(Device::small_edge());
        let map: BTreeMap<String, String> = conv_equivalent(&cnn)
            .keys()
            .map(|k| (k.clone(), "im2col".to_string()))
            .collect();
        let obs = vec![LayerObs {
            layer: "stem".into(),
            algo: "im2col".into(),
            count: 4,
            mean_us: 11.0,
            std_us: 1.0,
            min_us: 10.0,
            max_us: 13.0,
        }];
        let t = observed_vs_predicted(&cnn, &compiler, 16, 16, &map, &obs);
        assert_eq!(t.rows.len(), cnn.conv_count(), "one row per conv layer");
        let rendered = t.render();
        assert!(rendered.contains("stem"));
        assert!(rendered.contains("10.00"), "observed minimum shows up:\n{rendered}");
        // unobserved layers render placeholder columns
        assert!(rendered.contains(" - "));
    }
}
