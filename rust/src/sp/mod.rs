//! Series-parallel graph recognition (Definition 1, Lemmas 4.3/4.4).
//!
//! A two-terminal graph is series-parallel iff it reduces to `K_2` by
//! repeatedly (1) eliminating degree-2 vertices other than `s`/`t` and
//! (2) merging parallel edges. [`is_series_parallel`] runs that
//! reduction on an undirected multigraph; [`cnn_is_series_parallel`]
//! applies it to a CNN graph with the input layer as `s` and the output
//! as `t` — the property Theorem 4.1 needs for polynomial-time PBQP.

use crate::graph::Cnn;

/// Reduction trace, for reporting and tests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReduceOp {
    /// Eliminated vertex (operation 1) between its two neighbors.
    Series { removed: usize, left: usize, right: usize },
    /// Folded a pendant (degree-1) vertex into its neighbor. Pendant
    /// vertices arise in CNNs with auxiliary heads; folding them is the
    /// base step (1) of the paper's inductive construction.
    Pendant { removed: usize, into: usize },
    /// Merged a parallel edge pair (operation 2).
    Parallel { u: usize, v: usize },
}

/// Run the Definition-1 reduction. Returns `Some(trace)` if the graph
/// reduces to `K_2` on `{s, t}` (i.e. it is two-terminal
/// series-parallel), `None` otherwise.
pub fn reduce(n: usize, edge_list: &[(usize, usize)], s: usize, t: usize) -> Option<Vec<ReduceOp>> {
    assert!(s < n && t < n && s != t);
    // multigraph as edge multiset with alive flags
    let mut edges: Vec<(usize, usize, bool)> =
        edge_list.iter().map(|&(u, v)| (u.min(v), u.max(v), true)).collect();
    let mut alive = vec![false; n];
    alive[s] = true;
    alive[t] = true;
    for &(u, v, _) in &edges {
        alive[u] = true;
        alive[v] = true;
    }
    let mut trace = Vec::new();
    loop {
        // operation 2: merge one parallel pair per sweep
        let mut acted = false;
        'merge: for i in 0..edges.len() {
            if !edges[i].2 {
                continue;
            }
            for j in (i + 1)..edges.len() {
                if edges[j].2 && edges[i].0 == edges[j].0 && edges[i].1 == edges[j].1 {
                    edges[j].2 = false;
                    trace.push(ReduceOp::Parallel { u: edges[i].0, v: edges[i].1 });
                    acted = true;
                    break 'merge;
                }
            }
        }
        if acted {
            continue;
        }

        let live_vertices = alive.iter().filter(|&&a| a).count();
        let live_edges = edges.iter().filter(|e| e.2).count();
        if live_vertices == 2 && live_edges == 1 {
            return Some(trace); // K2 on {s, t}
        }

        // operation 1 (+ pendant folding)
        for k in 0..n {
            if !alive[k] || k == s || k == t {
                continue;
            }
            let inc: Vec<usize> = edges
                .iter()
                .enumerate()
                .filter(|(_, e)| e.2 && (e.0 == k || e.1 == k))
                .map(|(i, _)| i)
                .collect();
            match inc.len() {
                1 => {
                    let e = edges[inc[0]];
                    let nb = if e.0 == k { e.1 } else { e.0 };
                    edges[inc[0]].2 = false;
                    alive[k] = false;
                    trace.push(ReduceOp::Pendant { removed: k, into: nb });
                    acted = true;
                }
                2 => {
                    let e1 = edges[inc[0]];
                    let e2 = edges[inc[1]];
                    let a = if e1.0 == k { e1.1 } else { e1.0 };
                    let b = if e2.0 == k { e2.1 } else { e2.0 };
                    if a == b {
                        // two edges to the same neighbor → they are
                        // parallel after removing k; fold as pendant-ish:
                        // drop one edge (parallel merge at k) then k has
                        // degree 1. Handle directly: remove both, k dies,
                        // no new edge (cycle k-a collapses into a).
                        edges[inc[0]].2 = false;
                        edges[inc[1]].2 = false;
                        alive[k] = false;
                        trace.push(ReduceOp::Parallel { u: k.min(a), v: k.max(a) });
                        trace.push(ReduceOp::Pendant { removed: k, into: a });
                    } else {
                        edges[inc[0]].2 = false;
                        edges[inc[1]].2 = false;
                        edges.push((a.min(b), a.max(b), true));
                        alive[k] = false;
                        trace.push(ReduceOp::Series { removed: k, left: a, right: b });
                    }
                    acted = true;
                }
                0 => {
                    // isolated vertex (disconnected) — not reachable in a
                    // valid CNN; treat as reduction failure
                    return None;
                }
                _ => continue,
            }
            break;
        }
        if !acted {
            return None;
        }
    }
}

/// Is the undirected multigraph `(n, edges)` two-terminal
/// series-parallel with terminals `s`, `t`?
pub fn is_series_parallel(n: usize, edges: &[(usize, usize)], s: usize, t: usize) -> bool {
    reduce(n, edges, s, t).is_some()
}

/// Apply the reduction to a CNN graph (input = source, output = sink).
pub fn cnn_is_series_parallel(cnn: &Cnn) -> bool {
    is_series_parallel(cnn.nodes.len(), &cnn.edges, cnn.input(), cnn.output())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo;

    #[test]
    fn k2_is_sp() {
        assert!(is_series_parallel(2, &[(0, 1)], 0, 1));
    }

    #[test]
    fn chain_is_sp() {
        assert!(is_series_parallel(4, &[(0, 1), (1, 2), (2, 3)], 0, 3));
    }

    #[test]
    fn diamond_is_sp() {
        assert!(is_series_parallel(4, &[(0, 1), (1, 3), (0, 2), (2, 3)], 0, 3));
    }

    #[test]
    fn k4_is_not_sp() {
        let k4 = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)];
        assert!(!is_series_parallel(4, &k4, 0, 3));
    }

    #[test]
    fn wheatstone_bridge_is_not_sp() {
        // the classic non-SP example: diamond + cross edge
        let g = [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)];
        assert!(!is_series_parallel(4, &g, 0, 3));
    }

    /// Lemma 4.3: chain CNNs (VGG, AlexNet) and ResNet are SP.
    #[test]
    fn lemma_4_3() {
        assert!(cnn_is_series_parallel(&zoo::vgg16()));
        assert!(cnn_is_series_parallel(&zoo::alexnet()));
        assert!(cnn_is_series_parallel(&zoo::resnet18()));
    }

    /// Lemma 4.4: GoogLeNet and Inception-v4 are SP.
    #[test]
    fn lemma_4_4() {
        assert!(cnn_is_series_parallel(&zoo::googlenet()));
        assert!(cnn_is_series_parallel(&zoo::inception_v4()));
        assert!(cnn_is_series_parallel(&zoo::mini_inception()));
    }

    #[test]
    fn random_sp_constructions_recognized() {
        use crate::util::{proptest, rng::Rng};
        proptest::check("sp_recognizer", 128, |r: &mut Rng| {
            // build by the inductive construction: subdivide / duplicate
            let mut n = 2usize;
            let mut edges = vec![(0usize, 1usize)];
            for _ in 0..r.range(0, 12) {
                let eid = r.below(edges.len() as u64) as usize;
                if r.bool() {
                    let (u, v) = edges[eid];
                    edges.remove(eid);
                    edges.push((u, n));
                    edges.push((n, v));
                    n += 1;
                } else {
                    edges.push(edges[eid]);
                }
            }
            if !is_series_parallel(n, &edges, 0, 1) {
                return Err(format!("constructed SP graph rejected: n={n} edges={edges:?}"));
            }
            Ok(())
        });
    }
}
