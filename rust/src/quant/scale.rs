//! The quantization scheme: symmetric scales, i32 accumulation, f32
//! requantization — and the scalar reference GEMM.
//!
//! Weights are quantized **per output channel** (one scale per GEMM
//! output column, computed from that channel's max magnitude);
//! activations are quantized **per tensor** (one scale for the whole
//! matrix, calibrated offline or computed per request). Both sides are
//! symmetric around zero with the int8 grid `[-127, 127]` (−128 is
//! unused, so negation is exact). Products accumulate in i32 —
//! bit-exact regardless of summation order, which is what lets the
//! fast kernel vectorize its reduction while staying property-testably
//! identical to [`qgemm_requant_ref`] — and one f32 multiply per output
//! element requantizes the i32 sum back to real units.

use crate::algos::tensor::Mat;

/// Largest representable quantized magnitude (symmetric int8 grid).
pub const QMAX: f32 = 127.0;

/// Smallest scale ever produced: an all-zero tensor still needs a
/// non-zero scale so dequantization stays finite.
const MIN_SCALE: f32 = 1e-20;

/// Symmetric scale mapping `[-max_abs, max_abs]` onto the int8 grid.
pub fn symmetric_scale(max_abs: f32) -> f32 {
    (max_abs / QMAX).max(MIN_SCALE)
}

/// Largest magnitude in a slice (0 for an empty slice).
pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Quantize one value: round to nearest, clamp to the symmetric grid.
/// The result is an i8-range value carried in an i16 lane — the host
/// analogue of DSP packing, chosen so the kernel's widening multiplies
/// vectorize (see [`crate::kernels::qgemm`]).
#[inline]
pub fn quantize_value(v: f32, scale: f32) -> i16 {
    (v / scale).round().clamp(-QMAX, QMAX) as i16
}

/// Quantize a slice with one shared (per-tensor) scale.
pub fn quantize_slice(xs: &[f32], scale: f32) -> Vec<i16> {
    xs.iter().map(|&v| quantize_value(v, scale)).collect()
}

/// Scalar reference for the quantized GEMM: `X (a×b) · W (b×c)` with a
/// per-tensor activation scale, per-output-channel (per-column) weight
/// scales, i32 accumulation in ascending-`k` order and f32
/// requantization. [`crate::kernels::qgemm`] must match this
/// **bit-exactly** (integer sums are order-independent; the requantize
/// expression is kept identical on both sides).
pub fn qgemm_requant_ref(x: &Mat, w: &Mat) -> Mat {
    assert_eq!(x.cols, w.rows, "qgemm_requant_ref dim mismatch");
    let (a, b, c) = (x.rows, x.cols, w.cols);
    let sx = symmetric_scale(max_abs(&x.data));
    let xq = quantize_slice(&x.data, sx);
    let mut out = Mat::zeros(a, c);
    for j in 0..c {
        let col: Vec<f32> = (0..b).map(|k| w.get(k, j)).collect();
        let sw = symmetric_scale(max_abs(&col));
        let wq: Vec<i16> = col.iter().map(|&v| quantize_value(v, sw)).collect();
        let combined = sx * sw;
        for i in 0..a {
            let mut acc: i32 = 0;
            for k in 0..b {
                acc += xq[i * b + k] as i32 * wq[k] as i32;
            }
            out.set(i, j, acc as f32 * combined);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn scale_maps_extremes_onto_grid() {
        let s = symmetric_scale(2.54);
        assert_eq!(quantize_value(2.54, s), 127);
        assert_eq!(quantize_value(-2.54, s), -127);
        assert_eq!(quantize_value(0.0, s), 0);
        // out-of-range values clamp instead of wrapping
        assert_eq!(quantize_value(1e9, s), 127);
    }

    #[test]
    fn zero_tensor_has_finite_scale() {
        let s = symmetric_scale(max_abs(&[0.0, 0.0]));
        assert!(s > 0.0 && s.is_finite());
        assert_eq!(quantize_value(0.0, s), 0);
    }

    #[test]
    fn integer_grid_data_quantizes_exactly() {
        // data already on the grid (max |v| = 127, integer values):
        // scale = 1, quantization is lossless, so the quantized GEMM is
        // exact integer arithmetic and matches the f32 matmul bitwise
        let mut r = Rng::new(5);
        let mut x = Mat::from_fn(5, 7, |_, _| r.i8_small() as f32);
        let mut w = Mat::from_fn(7, 4, |_, _| r.i8_small() as f32);
        x.data[0] = 127.0;
        for j in 0..4 {
            w.set(0, j, 127.0);
        }
        let q = qgemm_requant_ref(&x, &w);
        let exact = x.matmul(&w);
        assert_eq!(q.data, exact.data, "on-grid data must round-trip exactly");
    }

    #[test]
    fn requant_error_is_bounded_on_random_data() {
        let mut r = Rng::new(6);
        let x = Mat::from_fn(9, 20, |_, _| r.f32_range(-1.0, 1.0));
        let w = Mat::from_fn(20, 8, |_, _| r.f32_range(-0.5, 0.5));
        let q = qgemm_requant_ref(&x, &w);
        let f = x.matmul(&w);
        let fmax = max_abs(&f.data).max(1e-6);
        for (a, b) in q.data.iter().zip(&f.data) {
            assert!(
                (a - b).abs() <= 0.05 * fmax,
                "quantization error {} vs {} exceeds 5% of range {fmax}",
                a,
                b
            );
        }
    }
}
