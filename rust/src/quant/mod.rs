//! Reduced-precision (int8) inference support: the precision axis of
//! the mapping space.
//!
//! The paper's overlay computes in reduced-precision fixed point, and
//! FPGA CNN accelerators earn their throughput from DSP packing — two
//! int8 multiply-accumulates per DSP slice per cycle (the fpgaConvNet
//! toolflow and the FPGA CNN acceleration survey in PAPERS.md both
//! build on this). This module makes precision a *searchable* dimension
//! of DYNAMAP's mapping space rather than a global switch:
//!
//! * [`Precision`] — the per-layer precision choice. The DSE widens
//!   each conv vertex's PBQP domain from {algorithm × dataflow} to
//!   {algorithm × dataflow × precision} (see
//!   [`crate::cost::graph_build`]), pricing int8 with the DSP-packing
//!   throughput of [`crate::cost::Device::int8_macs_per_dsp`] and
//!   charging quantize/dequantize transition costs on edges whose
//!   endpoints disagree ([`crate::cost::transition::TransitionModel::requant_sec`]).
//!   Winograd stays f32: its transform-space arithmetic amplifies
//!   quantization error, so [`Precision::Int8`] is never offered for a
//!   Winograd choice and the serving layer clamps any such request.
//! * [`scale`] — the quantization scheme: per-output-channel symmetric
//!   weight scales, per-tensor activation scales, i32 accumulation with
//!   f32 requantization, plus the scalar reference GEMM the fast kernel
//!   ([`crate::kernels::qgemm`]) is property-tested against.
//! * [`act`] — [`act::ActScales`]: per-layer activation scales
//!   calibrated from a handful of profiled f32 batches
//!   ([`crate::api::NativeState::calibrate_activations`]), with JSON
//!   round-tripping so a calibration is a durable artifact. Layers
//!   without a calibrated scale quantize dynamically (per-request
//!   max-abs).
//!
//! Serving-layer plumbing: a per-layer precision rides in the
//! `layer → algorithm` maps as a `-int8` suffix on the family name
//! ("im2col-int8"), so plans, profiles, the serve REPL and
//! `tune::remap` all agree on one spelling — [`mapped_name`] and
//! [`parse_mapped`] are the only encoder/decoder.
//!
//! The README's quantization quickstart (calibrate → compile with
//! precision search → serve), as a compiled example:
//!
//! ```no_run
//! use dynamap::api::{Backend, Compiler, Session};
//! use dynamap::graph::zoo;
//! use dynamap::quant::ActScales;
//! use dynamap::runtime::TensorBuf;
//! use dynamap::util::rng::Rng;
//!
//! // 1. calibrate per-tensor activation scales from a handful of
//! //    *representative* batches on the f32 native path (real inputs
//! //    in production — an all-zero layer falls back to dynamic)
//! let f32_session = Session::builder("artifacts").backend(Backend::Native).build()?;
//! let mut rng = Rng::new(7);
//! let batches: Vec<TensorBuf> = (0..4)
//!     .map(|_| {
//!         TensorBuf::new(
//!             vec![4, 16, 16],
//!             (0..4 * 16 * 16).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
//!         )
//!     })
//!     .collect();
//! let scales = f32_session
//!     .native_state()
//!     .expect("native backend always has shareable state")
//!     .calibrate_activations(&batches)?;
//! scales.save("plans/act_scales.json")?;
//!
//! // 2. compile with the precision axis enabled: the DSE may now map
//! //    individual layers to int8 (Winograd layers stay f32)
//! let plan = Compiler::new().precision_search(true).compile(&zoo::mini_inception())?;
//! println!("{:?}", plan.plan.algo_histogram());
//!
//! // 3. serve the mixed-precision plan with the calibrated scales
//! let mut session = Session::builder("artifacts")
//!     .backend(Backend::Native)
//!     .plan(plan)
//!     .act_scales(ActScales::load("plans/act_scales.json")?)
//!     .build()?;
//! let (outputs, metrics) = session.infer_batch(&[TensorBuf::zeros(vec![4, 16, 16])])?;
//! println!("{} outputs, {}", outputs.len(), metrics.stats.summary());
//! # Ok::<(), dynamap::api::DynamapError>(())
//! ```

#![deny(clippy::correctness, clippy::suspicious)]
#![warn(missing_docs)]

pub mod act;
pub mod scale;

pub use act::ActScales;
pub use scale::{max_abs, qgemm_requant_ref, quantize_value, symmetric_scale, QMAX};

/// Arithmetic precision a conv layer executes with — the second
/// dimension (after the algorithm) of a PBQP vertex domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    /// Full-precision f32 datapath (1 MAC per DSP in the cost model).
    #[default]
    F32,
    /// Quantized int8 datapath: i8 operands, i32 accumulation, f32
    /// requantization; priced with DSP packing
    /// ([`crate::cost::Device::int8_macs_per_dsp`] MACs per DSP).
    Int8,
}

impl Precision {
    /// Both precisions, in search order (f32 first, so exact ties keep
    /// the full-precision choice).
    pub const ALL: [Precision; 2] = [Precision::F32, Precision::Int8];

    /// Stable display/serialization name ("f32" / "int8").
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// How a quantized layer obtains its per-tensor activation scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ActQuant {
    /// Compute the scale per request from the actual input (max-abs
    /// pass). Self-calibrating, costs one pass over the input.
    Dynamic,
    /// Use a scale calibrated offline from profiled batches
    /// ([`ActScales`]); deterministic across requests.
    Static(f32),
}

/// The suffix [`mapped_name`] appends for [`Precision::Int8`] entries.
pub const INT8_SUFFIX: &str = "-int8";

/// Serving-layer spelling of an `(algorithm family, precision)` pair:
/// the family name verbatim for f32, `<family>-int8` for int8.
pub fn mapped_name(family: &str, precision: Precision) -> String {
    match precision {
        Precision::F32 => family.to_string(),
        Precision::Int8 => format!("{family}{INT8_SUFFIX}"),
    }
}

/// Decode a serving-layer algorithm name into `(family, precision)` —
/// the inverse of [`mapped_name`]. Unsuffixed names are f32.
pub fn parse_mapped(name: &str) -> (&str, Precision) {
    match name.strip_suffix(INT8_SUFFIX) {
        Some(family) => (family, Precision::Int8),
        None => (name, Precision::F32),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapped_name_round_trips() {
        for family in ["im2col", "kn2row", "winograd"] {
            for p in Precision::ALL {
                let name = mapped_name(family, p);
                assert_eq!(parse_mapped(&name), (family, p));
            }
        }
        assert_eq!(parse_mapped("im2col"), ("im2col", Precision::F32));
        assert_eq!(parse_mapped("kn2row-int8"), ("kn2row", Precision::Int8));
    }

    #[test]
    fn precision_names_and_order() {
        assert_eq!(Precision::F32.name(), "f32");
        assert_eq!(Precision::Int8.name(), "int8");
        assert_eq!(Precision::ALL[0], Precision::F32, "ties must resolve to f32");
        assert_eq!(Precision::default(), Precision::F32);
    }
}
