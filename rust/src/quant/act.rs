//! [`ActScales`] — per-layer, per-tensor activation scales calibrated
//! from profiled f32 batches.
//!
//! Static activation scales make quantized serving deterministic (the
//! same input always quantizes onto the same grid regardless of the
//! rest of the batch) and save the per-request max-abs pass. They are
//! produced by [`crate::api::NativeState::calibrate_activations`] —
//! run a handful of representative batches through the f32 path,
//! record each conv/FC layer's input magnitude high-water mark, map it
//! onto the int8 grid — and round-trip through JSON so a calibration
//! is a durable artifact next to the plan.

use std::collections::BTreeMap;
use std::path::Path;

use super::scale::symmetric_scale;
use crate::api::error::DynamapError;
use crate::util::json::Json;

/// Calibrated per-layer activation scales (`layer name → scale`).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ActScales {
    /// Largest observed input magnitude per layer (the calibration
    /// evidence; the scale is derived from it).
    max_abs: BTreeMap<String, f32>,
}

impl ActScales {
    /// An empty calibration (every layer falls back to dynamic
    /// quantization).
    pub fn new() -> ActScales {
        ActScales::default()
    }

    /// Record an observed input magnitude for `layer`, keeping the
    /// high-water mark across observations and batches.
    pub fn observe(&mut self, layer: &str, max_abs: f32) {
        let e = self.max_abs.entry(layer.to_string()).or_insert(0.0);
        *e = e.max(max_abs);
    }

    /// The calibrated scale for `layer`, if it was observed **with a
    /// non-zero magnitude**. A layer whose calibration batches only
    /// ever showed zero activations has no usable grid — a degenerate
    /// static scale would saturate every real request to ±127 and
    /// dequantize to ~0 — so it returns `None` and the layer falls back
    /// to dynamic per-request quantization.
    pub fn scale_for(&self, layer: &str) -> Option<f32> {
        self.max_abs
            .get(layer)
            .and_then(|&m| (m > 0.0).then_some(symmetric_scale(m)))
    }

    /// Number of calibrated layers.
    pub fn len(&self) -> usize {
        self.max_abs.len()
    }

    /// `true` when no layer has been observed.
    pub fn is_empty(&self) -> bool {
        self.max_abs.is_empty()
    }

    /// Serialize to JSON (`{"layer": max_abs, ...}`).
    pub fn to_json(&self) -> Json {
        Json::obj(
            self.max_abs
                .iter()
                .map(|(k, &v)| (k.as_str(), Json::num(v as f64)))
                .collect(),
        )
    }

    /// Parse the form produced by [`ActScales::to_json`].
    pub fn from_json(j: &Json) -> Result<ActScales, DynamapError> {
        let obj = j.as_obj().ok_or_else(|| {
            DynamapError::Artifact("activation scales: expected a JSON object".into())
        })?;
        let mut s = ActScales::new();
        for (layer, v) in obj {
            let m = v.as_f64().ok_or_else(|| {
                DynamapError::Artifact(format!(
                    "activation scales: non-numeric entry for '{layer}'"
                ))
            })?;
            s.observe(layer, m as f32);
        }
        Ok(s)
    }

    /// Write the calibration to `path` as pretty JSON.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), DynamapError> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|e| DynamapError::io(parent, e))?;
            }
        }
        std::fs::write(path, self.to_json().pretty()).map_err(|e| DynamapError::io(path, e))
    }

    /// Load a calibration previously written with [`ActScales::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<ActScales, DynamapError> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| DynamapError::io(path, e))?;
        let j = Json::parse(&text).map_err(|e| DynamapError::json_in(path, e))?;
        ActScales::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_keeps_high_water_mark() {
        let mut s = ActScales::new();
        s.observe("stem", 1.0);
        s.observe("stem", 3.0);
        s.observe("stem", 2.0);
        assert_eq!(s.scale_for("stem"), Some(symmetric_scale(3.0)));
        assert_eq!(s.scale_for("head"), None);
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn all_zero_observations_fall_back_to_dynamic() {
        // a layer that only ever saw zero activations has no usable
        // grid: no static scale, so the serving layer stays dynamic
        let mut s = ActScales::new();
        s.observe("dead", 0.0);
        assert_eq!(s.scale_for("dead"), None);
        // a later non-zero observation flips it to calibrated
        s.observe("dead", 0.5);
        assert_eq!(s.scale_for("dead"), Some(symmetric_scale(0.5)));
    }

    #[test]
    fn json_round_trip() {
        let mut s = ActScales::new();
        s.observe("a", 0.5);
        s.observe("b/c", 7.25);
        let back = ActScales::from_json(&Json::parse(&s.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back, s);
        assert!(ActScales::from_json(&Json::parse("[1,2]").unwrap()).is_err());
    }
}
