//! # DYNAMAP — Dynamic Algorithm Mapping for Low-Latency CNN Inference
//!
//! Reproduction of Meng et al., *DYNAMAP* (FPGA '21). The crate contains
//! the complete software stack of the paper behind a staged front-door
//! API ([`api`]): an offline [`api::Compiler`] runs the DSE once and
//! produces a versioned, cacheable [`api::PlanArtifact`]; an online
//! [`api::Session`] serves inference requests against the reused
//! overlay without ever re-running the search. Every fallible call
//! returns the typed [`api::DynamapError`].
//!
//! ## Quickstart
//!
//! Offline: compile a network into a plan artifact and persist it.
//!
//! ```no_run
//! use dynamap::api::Compiler;
//! use dynamap::graph::zoo;
//!
//! let cnn = zoo::googlenet();
//! let artifact = Compiler::new().compile(&cnn).unwrap();
//! println!(
//!     "P_SA = {}×{}, latency = {:.3} ms",
//!     artifact.plan.p1, artifact.plan.p2, artifact.plan.total_latency_ms
//! );
//! artifact.save("plans/googlenet.json").unwrap();
//! ```
//!
//! Online: open a serving session over an AOT artifact directory
//! (`make artifacts`); with a plan cache, later sessions skip the DSE.
//!
//! ```no_run
//! use dynamap::api::Session;
//! use dynamap::runtime::TensorBuf;
//!
//! let mut session = Session::builder("artifacts").plan_cache("plans").build().unwrap();
//! let input = TensorBuf::zeros(vec![4, 16, 16]);
//! let (outputs, metrics) = session.infer_batch(&[input]).unwrap();
//! println!("{} outputs, {}", outputs.len(), metrics.stats.summary());
//! ```
//!
//! At serving time the mapping stays dynamic: the [`tune`] subsystem
//! profiles per-layer latency on the live request path, fits the
//! analytic cost model to the observations, re-solves the DSE and
//! hot-swaps improved plans into the serving engine without dropping a
//! request.
//!
//! ## Layers
//!
//! * [`api`] — the staged `Compiler → PlanArtifact → Session` front
//!   door with typed errors and plan caching.
//! * [`graph`] — CNN graph IR and the model zoo (GoogLeNet, Inception-v4, …).
//! * [`cost`] — the analytical cost model: GEMM cycles under the three
//!   dataflows (Eq. 9), per-algorithm conv latency (Eq. 10–12), and
//!   inter-layer layout-transition costs (Table 2, Eq. 13).
//! * [`sp`] — series-parallel graph recognition and reduction (Def. 1).
//! * [`pbqp`] — Partitioned Boolean Quadratic Programming: the
//!   polynomial-time series-parallel solver (Thm 4.1), a brute-force
//!   verifier and a greedy baseline.
//! * [`dse`] — the two-step design-space exploration flow (Fig. 7):
//!   Algorithm 1 architecture-parameter identification + PBQP mapping.
//! * [`overlay`] — a cycle-level simulator of the hardware overlay:
//!   systolic array (NS/WS/IS dataflows, stall-free PEs), dual-parallelism
//!   blocked banking, DLT layout-transformation FSM, pad-and-accumulate,
//!   Winograd linear transforms, pooling units and the DDR model.
//! * [`algos`] — functional (bit-accurate) f32/int8 implementations of
//!   im2col, kn2row and Winograd convolution.
//! * [`kernels`] — the fast host-side kernel layer: cache-blocked
//!   transpose-free GEMM over packed `Wᵀ` panels and per-layer
//!   [`kernels::PreparedWeights`] (pre-lowered im2col/kn2row/Winograd
//!   weights) built once at plan time, plus the quantized int8 GEMM
//!   ([`kernels::qgemm`]) beside it.
//! * [`quant`] — the precision axis of the mapping space: per-channel
//!   symmetric weight scales, calibrated per-tensor activation scales,
//!   and the `(family, precision)` spelling shared by plans, serving
//!   maps and the tuner.
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them.
//! * [`serve`] — multi-model serving engine on top of [`api::Session`]:
//!   model registry with LRU eviction and a shared plan cache, dynamic
//!   batching queues, per-model QPS/tail-latency metrics, admission
//!   control with typed `Overloaded` shedding, and the closed- and
//!   open-loop load generators behind `dynamap serve`/`loadgen`.
//! * [`net`] — production TCP front-end over [`serve`]: versioned
//!   length-prefixed wire protocol, blocking threaded [`net::NetServer`]
//!   with graceful drain, and the pooled [`net::Client`]
//!   (`dynamap serve --listen`, `loadgen --connect`).
//! * [`tune`] — online adaptation: per-layer latency profiling on the
//!   native serving path, least-squares cost-model calibration,
//!   DSE re-solve and zero-downtime plan hot-swap (`dynamap tune`,
//!   `dynamap serve --tune`).
//! * [`fault`] — deterministic, seeded fault injection (slow layers,
//!   worker panics, dropped/stalled connections, corrupted replies,
//!   artifact I/O errors) behind default-off hooks; powers the chaos
//!   harness in `rust/tests/chaos.rs`.
//! * [`obs`] — end-to-end request tracing (per-request [`obs::TraceId`]
//!   propagated as the protocol-v3 trailer, admission/queue/flush/layer
//!   spans into a bounded ring, Chrome trace-event export for Perfetto)
//!   and the O(1) log-bucketed [`obs::LogHistogram`] behind the serving
//!   metrics (`dynamap trace` / `dynamap stats`).
//! * [`coordinator`] — latency metrics + the simulate/infer CLI.
//! * [`emit`] — Verilog-style RTL + control-stream emission.
//! * [`bench`] — mini-criterion harness + figure/table regeneration.
//! * [`util`] — in-repo substrates (JSON, CLI, RNG/property testing,
//!   ASCII tables) replacing crates unavailable in the offline build.

pub mod util;
pub mod graph;
pub mod quant;
pub mod cost;
pub mod sp;
pub mod pbqp;
pub mod dse;
pub mod api;
pub mod overlay;
pub mod algos;
pub mod kernels;
pub mod runtime;
pub mod serve;
pub mod net;
pub mod fault;
pub mod obs;
pub mod tune;
pub mod coordinator;
pub mod emit;
pub mod bench;
