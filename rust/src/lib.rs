//! # DYNAMAP — Dynamic Algorithm Mapping for Low-Latency CNN Inference
//!
//! Reproduction of Meng et al., *DYNAMAP* (FPGA '21). The crate contains
//! the complete software stack of the paper:
//!
//! * [`graph`] — CNN graph IR and the model zoo (GoogLeNet, Inception-v4, …).
//! * [`cost`] — the analytical cost model: GEMM cycles under the three
//!   dataflows (Eq. 9), per-algorithm conv latency (Eq. 10–12), and
//!   inter-layer layout-transition costs (Table 2, Eq. 13).
//! * [`sp`] — series-parallel graph recognition and reduction (Def. 1).
//! * [`pbqp`] — Partitioned Boolean Quadratic Programming: the
//!   polynomial-time series-parallel solver (Thm 4.1), a brute-force
//!   verifier and a greedy baseline.
//! * [`dse`] — the two-step design-space exploration flow (Fig. 7):
//!   Algorithm 1 architecture-parameter identification + PBQP mapping.
//! * [`overlay`] — a cycle-level simulator of the hardware overlay:
//!   systolic array (NS/WS/IS dataflows, stall-free PEs), dual-parallelism
//!   blocked banking, DLT layout-transformation FSM, pad-and-accumulate,
//!   Winograd linear transforms, pooling units and the DDR model.
//! * [`algos`] — functional (bit-accurate) f32/int8 implementations of
//!   im2col, kn2row and Winograd convolution.
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled HLO
//!   artifacts produced by `python/compile/aot.py` and executes them.
//! * [`coordinator`] — the end-to-end inference engine that chains
//!   per-layer executables according to the DSE-chosen algorithm mapping.
//! * [`emit`] — Verilog-style RTL + control-stream emission.
//! * [`bench`] — mini-criterion harness + figure/table regeneration.
//! * [`util`] — in-repo substrates (JSON, CLI, RNG/property testing,
//!   ASCII tables) replacing crates unavailable in the offline build.
//!
//! ## Quickstart
//!
//! ```no_run
//! use dynamap::graph::zoo;
//! use dynamap::dse::{Dse, DseConfig};
//!
//! let cnn = zoo::googlenet();
//! let dse = Dse::new(DseConfig::alveo_u200());
//! let plan = dse.run(&cnn).unwrap();
//! println!("latency = {:.3} ms", plan.total_latency_ms);
//! ```

pub mod util;
pub mod graph;
pub mod cost;
pub mod sp;
pub mod pbqp;
pub mod dse;
pub mod overlay;
pub mod algos;
pub mod runtime;
pub mod coordinator;
pub mod emit;
pub mod bench;
