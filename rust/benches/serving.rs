//! `cargo bench` target for the multi-model serving engine: the same
//! seeded closed-loop workload (8 clients × 8 requests on
//! mini-inception, ROADMAP §Performance methodology — fixed seed 99,
//! release profile, `DYNAMAP_BENCH_FAST` unset for real numbers) driven
//! through two registry configurations:
//!
//! * **one-at-a-time** — `max_batch = 1`: every request is its own
//!   flush, serving strictly sequentially (the pre-engine model of one
//!   caller per session).
//! * **batched** — `max_batch = 8`, `max_wait = 2ms`: the dynamic
//!   batching scheduler coalesces concurrent requests into
//!   `infer_batch` calls that fan out over the worker pool.
//!
//! The run prints `serving throughput speedup: N.NNx` so ROADMAP.md
//! §Performance has a number to append, and `tracing overhead: …` for
//! the observability layer (`obs`): the measured cost of the disabled
//! instrumentation path (one relaxed atomic load per would-be span)
//! scaled to a request, beside the cost of serving with a recorder
//! installed. `DYNAMAP_BENCH_ASSERT=1` turns the ≥1.3× batching
//! threshold into a hard failure when the host has ≥4 cores (plain
//! runs only report; single-core runners can't batch-win) and gates
//! the disabled-tracing overhead below 1% of a request.
//!
//! A third scenario measures SLO co-scheduling (`serve::sched`): an
//! interactive tenant (100 ms target) beside a bulk tenant offered far
//! past capacity, through the thread partitioner + per-partition plan
//! re-solve + pressure-deferral path, printing the
//! `slo attainment: high=NN.N% bulk=NN.N%` headline. Under
//! `DYNAMAP_BENCH_ASSERT=1` (and ≥4 cores) the interactive tenant must
//! attain ≥95% while the bulk tenant demonstrably saturates.

use std::time::{Duration, Instant};

use dynamap::api::{Compiler, Device};
use dynamap::bench::harness::Bencher;
use dynamap::obs::ObsGuard;
use dynamap::serve::{
    loadgen, open_loop_mixed, BatchConfig, LoadgenConfig, MixedConfig, ModelRegistry,
    ModelSlo, RegistryConfig, SloTable, TenantLoad,
};
use dynamap::util::parallel::worker_count;

fn registry(root: &std::path::Path, max_batch: usize) -> ModelRegistry {
    ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 2,
        synthesize_missing: true,
        seed: 99,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig { max_batch, max_wait: Duration::from_millis(2) },
        max_inflight: 0,
        profile: false,
        slos: Default::default(),
    })
}

fn main() {
    let mut b = Bencher::new();
    let root = std::env::temp_dir()
        .join(format!("dynamap_serving_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();

    let load = LoadgenConfig {
        models: vec!["mini-inception".to_string()],
        clients: 8,
        requests: 8,
        seed: 99,
    };

    // one-at-a-time first: it also synthesizes the artifacts and fills
    // the shared plan cache, so the batched registry builds DSE-free
    let seq_registry = registry(&root, 1);
    let seq = b
        .bench("serving/mini-inception/8x8req/one-at-a-time", || {
            loadgen::run(&seq_registry, &load).expect("sequential loadgen").requests
        })
        .clone();
    let seq_snapshot = seq_registry.metrics().snapshots();
    seq_registry.shutdown();

    let batched_registry = registry(&root, 8);
    let fast = b
        .bench("serving/mini-inception/8x8req/batched_max8", || {
            loadgen::run(&batched_registry, &load).expect("batched loadgen").requests
        })
        .clone();
    let fast_snapshot = batched_registry.metrics().snapshots();
    batched_registry.shutdown();

    for s in seq_snapshot.iter().chain(&fast_snapshot) {
        println!("  {}", s.summary());
    }
    let speedup = seq.mean.as_secs_f64() / fast.mean.as_secs_f64();
    println!(
        "serving throughput speedup (dynamic batching max_batch=8 vs one-at-a-time): \
         {speedup:.2}x"
    );
    // enforced gate: only meaningful with real parallelism under the
    // flush — a single-core runner degenerates both arms to sequential
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok() && worker_count(8) >= 4 {
        assert!(
            speedup >= 1.3,
            "dynamic batching speedup regressed below the 1.3x gate: {speedup:.2}x"
        );
    }

    // --- tracing overhead --------------------------------------------
    // enabled path: the identical batched workload with a recorder
    // installed, so every request buffers its admission/queue/layer
    // spans for real
    let traced_registry = registry(&root, 8);
    let n_layers =
        traced_registry.host("mini-inception").expect("hosted").state().algo_map().len();
    let guard = ObsGuard::install(dynamap::obs::DEFAULT_CAPACITY);
    let traced = b
        .bench("serving/mini-inception/8x8req/batched_traced", || {
            loadgen::run(&traced_registry, &load).expect("traced loadgen").requests
        })
        .clone();
    let spans = guard.recorder().len();
    drop(guard);
    traced_registry.shutdown();

    // disabled path: every instrumentation point is one relaxed atomic
    // load before anything else happens — measure that check directly
    // and scale it to a request's worth of would-be spans (per-layer
    // plus admission, queue and flush). This is the overhead every
    // production request pays when tracing is off.
    assert!(
        !dynamap::obs::is_active(),
        "recorder must be uninstalled so the disabled path is measured for real"
    );
    const CHECKS: u64 = 10_000_000;
    let t0 = Instant::now();
    for _ in 0..CHECKS {
        std::hint::black_box(dynamap::obs::is_active());
    }
    let per_check = t0.elapsed().as_secs_f64() / CHECKS as f64;
    let checks_per_request = (n_layers + 3) as f64;
    // conservative denominator: batched wall-clock per request (smaller
    // than a request's latency, so the reported percentage over-states)
    let per_request = fast.mean.as_secs_f64() / (load.clients * load.requests) as f64;
    let disabled_pct = 100.0 * per_check * checks_per_request / per_request;
    let enabled_pct = 100.0 * (traced.mean.as_secs_f64() / fast.mean.as_secs_f64() - 1.0);
    println!(
        "tracing overhead: {disabled_pct:.2}% disabled ({checks_per_request:.0} \
         checks/request at {:.1} ns each), {enabled_pct:.2}% enabled \
         ({spans} spans buffered)",
        per_check * 1e9
    );
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok() {
        assert!(
            disabled_pct < 1.0,
            "disabled tracing must cost <1% of a request, measured {disabled_pct:.2}%"
        );
    }

    // --- multi-tenant SLO co-scheduling ------------------------------
    // two opposed tenants through one registry: an interactive tenant
    // (100 ms target, priority 8) at a modest offered rate beside a
    // bulk best-effort tenant offered far past capacity (its excess
    // sheds against the per-host admission budget). The partitioner
    // splits the worker pool, both plans re-solve under their
    // partitions, and bulk flushes defer while the interactive queue is
    // pressured — the attainment line is the multi-CNN headline and the
    // CI slo-smoke gate.
    let fast_mode = std::env::var("DYNAMAP_BENCH_FAST").is_ok();
    let slos: SloTable = [
        ("mini-inception".to_string(), ModelSlo::interactive_ms(100.0)),
        ("mini-vgg".to_string(), ModelSlo::bulk()),
    ]
    .into_iter()
    .collect();
    let tenant_registry = ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 2,
        synthesize_missing: true,
        seed: 99,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        max_inflight: 16,
        profile: false,
        slos,
    });
    tenant_registry.host("mini-inception").expect("host interactive tenant");
    tenant_registry.host("mini-vgg").expect("host bulk tenant");
    let budgets = tenant_registry.repartition();
    let replanned =
        tenant_registry.resolve_partition_plans().expect("partition plan re-solve");
    println!(
        "serving/mixed-tenant/slo-coschedule: partition {budgets:?}, \
         {replanned} plan(s) re-solved"
    );
    let mixed = MixedConfig {
        tenants: vec![
            TenantLoad {
                model: "mini-inception".into(),
                rate_qps: 200.0,
                requests: if fast_mode { 40 } else { 160 },
                slo: Some(Duration::from_millis(100)),
                deadline: None,
            },
            TenantLoad {
                model: "mini-vgg".into(),
                rate_qps: 4000.0,
                requests: if fast_mode { 150 } else { 600 },
                slo: None,
                deadline: None,
            },
        ],
        seed: 99,
        workers: 64,
    };
    let mixed_report = open_loop_mixed(&tenant_registry, &mixed).expect("mixed open loop");
    println!("{}", mixed_report.summary());
    tenant_registry.shutdown();
    // enforced gate: the interactive tenant holds its SLO while bulk
    // saturates — again only meaningful with ≥4 cores to partition
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok() && worker_count(8) >= 4 {
        let (high, _bulk) = mixed_report.attainment();
        assert!(
            high >= 95.0,
            "interactive SLO attainment regressed below the 95% gate: {high:.1}%"
        );
        assert!(
            mixed_report.tenants[1].report.shed >= 1,
            "the bulk tenant never saturated — the co-scheduling gate measured nothing"
        );
    }
    std::fs::remove_dir_all(&root).ok();
}
