//! `cargo bench` target for the TCP front-end: the open-loop overload
//! study of the ROADMAP §Performance methodology (fixed seed 99,
//! release profile, loopback, `DYNAMAP_BENCH_FAST` unset for real
//! numbers).
//!
//! The run first estimates the server's closed-loop capacity with a
//! short burst, then offers seeded-Poisson open-loop load at 0.25×,
//! 0.5×, 1×, 2× and 4× that capacity through [`dynamap::net::Client`]
//! against a [`dynamap::net::NetServer`] on an ephemeral loopback port
//! (mini-inception, `max_inflight = 32`). `DYNAMAP_BENCH_FAST=1`
//! shrinks the sweep to the 0.5× and 4× points with short windows (the
//! CI smoke shape). A final point rides at 2× capacity with a 50 ms
//! per-request deadline and shed retries enabled, reporting deadline
//! misses and client retry spend. For each point it prints
//! offered vs achieved QPS, shed fraction and p50/p99/p99.9 latency
//! (measured from the *scheduled* arrival instant, so queue collapse is
//! charged to the tail — no coordinated omission), plus the worst
//! shed-reply time. The summary names the knee: the highest offered
//! load the server still absorbs at ≥95%.
//!
//! `DYNAMAP_BENCH_ASSERT=1` turns the overload contract into hard
//! failures: beyond the knee the server must shed (not queue without
//! bound), every shed reply must land within the 100 ms deadline, and
//! the server must still answer a ping after the sweep.

use std::sync::Arc;
use std::time::{Duration, Instant};

use dynamap::api::{Compiler, Device};
use dynamap::net::{Client, NetServer, RetryPolicy};
use dynamap::serve::loadgen::{
    model_input_dims, open_loop, open_loop_input, OpenLoopConfig, OpenLoopReport,
};
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::util::parallel::parallel_run;

const MODEL: &str = "mini-inception";
const MAX_INFLIGHT: usize = 32;
/// Every shed reply must land within this deadline (µs) — the whole
/// point of admission control is that "no" arrives fast.
const SHED_DEADLINE_US: f64 = 100_000.0;

fn main() {
    let fast = std::env::var("DYNAMAP_BENCH_FAST").is_ok();
    let assert_gate = std::env::var("DYNAMAP_BENCH_ASSERT").is_ok();
    let root =
        std::env::temp_dir().join(format!("dynamap_net_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();

    let reg = Arc::new(ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: Some(root.join("plans")),
        capacity: 0,
        synthesize_missing: true,
        seed: 99,
        compiler: Compiler::new().device(Device::small_edge()),
        batch: BatchConfig { max_batch: 8, max_wait: Duration::from_millis(2) },
        max_inflight: MAX_INFLIGHT,
        profile: false,
        slos: Default::default(),
    }));
    reg.host(MODEL).expect("host mini-inception"); // compile before timing
    let dims = model_input_dims(MODEL).expect("zoo dims");

    let mut server = NetServer::bind(reg.clone(), "127.0.0.1:0").expect("bind loopback");
    let client = Client::connect(server.local_addr().to_string()).expect("connect");

    // closed-loop capacity estimate: 8 connections, back-to-back
    // requests — the denominator the sweep multiplies
    let (burst_clients, burst_per) = if fast { (4, 8) } else { (8, 32) };
    let t0 = Instant::now();
    parallel_run(burst_clients, |w| {
        for j in 0..burst_per {
            client
                .infer(MODEL, &open_loop_input(99, w * burst_per + j, dims))
                .expect("burst infer");
        }
    });
    let capacity = (burst_clients * burst_per) as f64 / t0.elapsed().as_secs_f64();
    println!(
        "net/{MODEL}: closed-loop capacity ≈ {capacity:.0} qps \
         ({burst_clients} conns × {burst_per} reqs, loopback, max_inflight={MAX_INFLIGHT})"
    );

    // the open-loop sweep: offered load as a multiple of capacity
    // (fast mode keeps only the below-knee and deep-overload points)
    let (secs_per_point, req_cap) = if fast { (0.25, 400) } else { (2.0, 4000) };
    let mults: &[f64] = if fast { &[0.5, 4.0] } else { &[0.25, 0.5, 1.0, 2.0, 4.0] };
    println!(
        "{:>12} {:>12} {:>6} {:>7} {:>8} {:>9} {:>9} {:>10} {:>12}",
        "offered qps", "achieved", "ok", "shed%", "dl miss", "p50 µs", "p99 µs",
        "p99.9 µs", "shed max µs"
    );
    let print_point = |r: &OpenLoopReport| {
        let tail = r.latency.percentiles(&[50.0, 99.0, 99.9]);
        println!(
            "{:>12.0} {:>12.1} {:>6} {:>6.1}% {:>8} {:>9.0} {:>9.0} {:>10.0} {:>12.0}",
            r.offered_qps,
            r.achieved_qps,
            r.ok,
            100.0 * r.shed as f64 / r.sent as f64,
            r.deadline_miss,
            tail[0],
            tail[1],
            tail[2],
            r.shed_latency.max(),
        );
    };
    let mut points: Vec<OpenLoopReport> = Vec::new();
    for &mult in mults {
        let offered = capacity * mult;
        let cfg = OpenLoopConfig {
            model: MODEL.to_string(),
            rate_qps: offered,
            requests: ((offered * secs_per_point) as usize).clamp(32, req_cap),
            seed: 99,
            workers: 64,
            deadline: None,
            trace: false,
        };
        let r = open_loop(&client, &cfg).expect("open loop");
        print_point(&r);
        points.push(r);
    }

    // deadline + retry point: 2× capacity with a 50 ms per-request
    // deadline and two shed retries under backoff — what deadlines and
    // polite retries recover (and cost) under overload
    let retry_client = Client::connect_with(
        server.local_addr().to_string(),
        RetryPolicy { overloaded_attempts: 2, ..RetryPolicy::default() },
    )
    .expect("connect retry client");
    let offered = capacity * 2.0;
    let cfg = OpenLoopConfig {
        model: MODEL.to_string(),
        rate_qps: offered,
        requests: ((offered * secs_per_point) as usize).clamp(32, req_cap),
        seed: 99,
        workers: 64,
        deadline: Some(Duration::from_millis(50)),
        trace: false,
    };
    let r = open_loop(&retry_client, &cfg).expect("deadline point");
    print_point(&r);
    let stats = retry_client.stats();
    println!(
        "  ^ deadline point: 50 ms deadline, 2 shed retries → dl_miss={} retries={} \
         budget left={}",
        r.deadline_miss, stats.retries, stats.budget_remaining
    );

    for s in reg.metrics().snapshots() {
        println!("  {}", s.summary());
    }

    // knee: the highest offered load still absorbed at >= 95%
    let knee = points
        .iter()
        .filter(|r| r.achieved_qps >= 0.95 * r.offered_qps)
        .map(|r| r.offered_qps)
        .fold(0.0f64, f64::max);
    let worst_shed_us =
        points.iter().map(|r| r.shed_latency.max()).fold(0.0f64, f64::max);
    let beyond: Vec<&OpenLoopReport> =
        points.iter().filter(|r| r.offered_qps > knee).collect();
    let shed_beyond: usize = beyond.iter().map(|r| r.shed).sum();
    if knee > 0.0 {
        println!(
            "net knee point: {knee:.0} qps offered still achieves ≥95%; beyond it the \
             server shed {shed_beyond} requests (worst shed reply {worst_shed_us:.0} µs)"
        );
    } else {
        println!(
            "net knee point: below the sweep floor ({:.0} qps) on this host; \
             {shed_beyond} requests shed (worst shed reply {worst_shed_us:.0} µs)",
            capacity * 0.25
        );
    }

    if assert_gate {
        // beyond the knee the server must say "no" rather than queue
        // without bound — the last point is 4× capacity, overload is
        // certain there
        assert!(
            points.last().map(|r| r.shed > 0).unwrap_or(false),
            "4x-capacity open loop shed nothing: admission control is not engaging"
        );
        assert!(
            worst_shed_us <= SHED_DEADLINE_US,
            "shed reply blew the {SHED_DEADLINE_US:.0}µs deadline: {worst_shed_us:.0}µs"
        );
        // typed sheds only — generic errors under overload are a bug
        let errors: usize = points.iter().map(|r| r.errors).sum();
        assert_eq!(errors, 0, "open loop saw non-Overloaded failures under load");
        // and the server survived the whole study
        client.ping().expect("server must still answer after the sweep");
    }

    client.shutdown_server().expect("drain request");
    server.shutdown();
    reg.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
