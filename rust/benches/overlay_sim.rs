//! `cargo bench` target for the overlay simulator hot paths: systolic
//! GEMM (per dataflow), DLT transforms, pad-accumulate, pooling — the
//! L3 profiling input data for the performance pass.

use dynamap::algos::tensor::{Mat, Tensor, Weights};
use dynamap::bench::harness::Bencher;
use dynamap::cost::gemm::Dataflow;
use dynamap::graph::layer::{ConvSpec, PoolKind, PoolSpec};
use dynamap::overlay::dlt::Ltu;
use dynamap::overlay::pooling;
use dynamap::overlay::systolic::SystolicSim;
use dynamap::overlay::layer_sim::simulate_layer;
use dynamap::cost::conv::Algo;
use dynamap::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(99);

    // systolic GEMM, three dataflows
    let x = Mat::from_fn(128, 96, |_, _| rng.i8_small() as f32);
    let w = Mat::from_fn(96, 128, |_, _| rng.i8_small() as f32);
    for df in Dataflow::ALL {
        let sim = SystolicSim::new(16, 16, df, true);
        b.bench(&format!("systolic_gemm/128x96x128/{}", df.name()), || sim.gemm(&x, &w));
    }

    // DLT transforms
    let spec = ConvSpec::new(16, 32, 32, 32, 3, 3, 1, 1, 1);
    let t = Tensor::random(16, 32, 32, &mut rng);
    let ltu = Ltu::tensor3d_to_toeplitz(&spec);
    let mut dst = vec![0.0f32; 16 * 9 * 32 * 32];
    b.bench("dlt/tensor3d_to_toeplitz/16x32x32_3x3", || {
        ltu.gather(&t.data, &mut dst);
        dst[0]
    });
    let ltu_w = Ltu::tensor3d_to_wino(16, 32, 32, 2, 3, 1);
    let mut dst_w = vec![0.0f32; ltu_w.len()];
    b.bench("dlt/tensor3d_to_wino/16x32x32", || {
        ltu_w.gather(&t.data, &mut dst_w);
        dst_w[0]
    });

    // whole-layer simulation per algorithm
    let lspec = ConvSpec::new(8, 8, 16, 16, 3, 3, 1, 1, 1);
    let input = Tensor::random(8, 16, 16, &mut rng);
    let wts = Weights::random(8, 8, 3, 3, &mut rng);
    for algo in [Algo::Im2col, Algo::Kn2row, Algo::Winograd { m: 2, r: 3 }] {
        b.bench(&format!("layer_sim/8x16x16_3x3/{}", algo.name()), || {
            simulate_layer(&input, &wts, &lspec, algo, Dataflow::NS, 16, 16)
        });
    }

    // pooling pipeline
    let pspec = PoolSpec { kind: PoolKind::Max, c: 64, h1: 28, h2: 28, k: 3, s: 2, p: 1 };
    let pin = Tensor::random(64, 28, 28, &mut rng);
    b.bench("pooling/hpu_vpu/64x28x28", || pooling::simulate(&pin, &pspec, 16));
}
