//! `cargo bench` target for the overlay simulator hot paths: systolic
//! GEMM (per dataflow), the kernel layer vs the pre-change
//! transpose-per-call path, DLT transforms, prepared vs one-shot layer
//! simulation, pooling — and the headline before/after comparison of
//! this perf pass: end-to-end `infer_batch` on mini-inception,
//! pre-change baseline (sequential, weight transforms re-derived per
//! request) vs the prepared-weight parallel serving path. The run
//! prints the measured speedup so ROADMAP.md §Performance has a number
//! to append.

use std::collections::BTreeMap;

use dynamap::algos::tensor::{Mat, Tensor, Weights};
use dynamap::algos::{im2col as im2col_algo, kn2row as kn2row_algo, winograd as wino_algo};
use dynamap::bench::harness::Bencher;
use dynamap::cost::conv::Algo;
use dynamap::cost::gemm::Dataflow;
use dynamap::graph::layer::{ConvSpec, Op, PoolKind, PoolSpec};
use dynamap::graph::zoo;
use dynamap::graph::Cnn;
use dynamap::kernels::{self, PackedWt, PreparedWeights};
use dynamap::overlay::dlt::Ltu;
use dynamap::overlay::layer_sim::{prepare_layer, simulate_layer, simulate_layer_prepared};
use dynamap::overlay::pooling;
use dynamap::overlay::systolic::SystolicSim;
use dynamap::util::parallel::parallel_map;
use dynamap::util::rng::Rng;

/// Representative per-layer algorithm choice by kernel size (exercises
/// all three families on mini-inception).
fn algo_for(spec: &ConvSpec) -> Algo {
    match spec.k1 {
        1 => Algo::Im2col,
        3 => Algo::Winograd { m: 2, r: 3 },
        _ => Algo::Kn2row,
    }
}

/// Pre-change request path: conv layers re-derive their weight lowering
/// on every request (exactly what the old `simulate`/serving loop did)
/// via the naive functional algorithms.
fn infer_rederive(cnn: &Cnn, weights: &BTreeMap<String, Weights>, input: &Tensor) -> Tensor {
    run_graph(cnn, input, |name, spec, x| {
        let w = &weights[name];
        match algo_for(spec) {
            Algo::Im2col => im2col_algo::conv2d(x, w, spec),
            Algo::Kn2row => kn2row_algo::conv2d(x, w, spec),
            _ => wino_algo::conv2d(x, w, spec),
        }
    })
}

/// Post-change request path: conv layers execute on weights lowered
/// once, outside the request loop.
fn infer_prepared(
    cnn: &Cnn,
    prepared: &BTreeMap<String, PreparedWeights>,
    input: &Tensor,
) -> Tensor {
    run_graph(cnn, input, |name, _, x| prepared[name].conv2d(x))
}

/// Minimal graph interpreter for the bench's two serving variants.
/// Deliberately free-standing: the baseline variant (per-request weight
/// re-derivation) must not exist in the product API, and both variants
/// must share one walker for a fair ratio. Keep the op semantics in
/// sync with `NativeState::infer` in `rust/src/api/session.rs`.
fn run_graph(
    cnn: &Cnn,
    input: &Tensor,
    mut conv: impl FnMut(&str, &ConvSpec, &Tensor) -> Tensor,
) -> Tensor {
    let mut values: BTreeMap<usize, Tensor> = BTreeMap::new();
    let mut out = None;
    for id in cnn.topo_order() {
        let node = cnn.node(id);
        let preds = cnn.predecessors(id);
        let t = match &node.op {
            Op::Input { .. } => input.clone(),
            Op::Conv(spec) => conv(&node.name, spec, &values[&preds[0]]),
            Op::Pool(p) => pooling::reference(&values[&preds[0]], p),
            Op::Concat { c_out, h1, h2 } => {
                let mut data = Vec::with_capacity(c_out * h1 * h2);
                for &p in &preds {
                    data.extend_from_slice(&values[&p].data);
                }
                Tensor { c: *c_out, h: *h1, w: *h2, data }
            }
            Op::Add { c, h1, h2 } => {
                let a = &values[&preds[0]];
                let b = &values[&preds[1]];
                Tensor {
                    c: *c,
                    h: *h1,
                    w: *h2,
                    data: a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect(),
                }
            }
            Op::Output => {
                out = Some(values[&preds[0]].clone());
                continue;
            }
            Op::Fc { .. } => unreachable!("no FC in the bench models"),
        };
        values.insert(id, t);
    }
    out.expect("graph has an output")
}

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(99);

    // systolic GEMM, three dataflows
    let x = Mat::from_fn(128, 96, |_, _| rng.i8_small() as f32);
    let w = Mat::from_fn(96, 128, |_, _| rng.i8_small() as f32);
    for df in Dataflow::ALL {
        let sim = SystolicSim::new(16, 16, df, true);
        b.bench(&format!("systolic_gemm/128x96x128/{}", df.name()), || sim.gemm(&x, &w));
    }

    // kernel layer, three generations on one fixed shape: the
    // pre-change hot path, the packed scalar kernel, the SIMD tier.
    // The transpose is hoisted out of the baseline closure (it used to
    // be re-derived per iteration, silently inflating the packed
    // kernel's ratio); what remains inside is exactly the contiguous-
    // slice dot loop the old per-PE path ran.
    let wtr = w.transposed();
    let wt = PackedWt::pack(&w);
    b.bench("kernels_gemm/128x96x128/baseline_transpose", || {
        let (ar, br, cr) = (x.rows, x.cols, wtr.rows);
        let mut out = Mat::zeros(ar, cr);
        for i in 0..ar {
            let x_row = &x.data[i * br..(i + 1) * br];
            for j in 0..cr {
                let w_col = &wtr.data[j * br..(j + 1) * br];
                let acc: f32 = x_row.iter().zip(w_col).map(|(p, q)| p * q).sum();
                out.set(i, j, acc);
            }
        }
        out
    });
    let packed = b.bench("kernels_gemm/128x96x128/packed", || kernels::gemm(&x, &wt)).clone();
    let choice = kernels::KernelSelector::probed().choose(x.rows, x.cols, wt.c);
    let simd = b
        .bench(&format!("kernels_gemm/128x96x128/simd_{}", choice.name()), || {
            kernels::simd::gemm(&x, &wt)
        })
        .clone();
    let simd_speedup = packed.mean.as_secs_f64() / simd.mean.as_secs_f64();
    println!("simd gemm speedup: {simd_speedup:.2}x  (kernel {}, target >= 2x)", choice.name());
    // enforced gate (see the infer_batch gate below for the pattern):
    // ≥2× over the packed scalar kernel whenever the probe found a SIMD
    // instruction set — the scalar fallback cannot promise a ratio, so
    // scalar-only hosts (and DYNAMAP_SIMD=off runs) report but don't gate
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok()
        && choice.kind != kernels::KernelKind::Scalar
    {
        assert!(
            simd_speedup >= 2.0,
            "simd gemm speedup regressed below the 2x acceptance gate: {simd_speedup:.2}x"
        );
    }

    // informational sections (DLT, layer sim, pooling): skipped under
    // DYNAMAP_BENCH_FAST so the CI smoke sweep stays lean — the gated
    // comparisons above and below always run
    let fast = std::env::var("DYNAMAP_BENCH_FAST").is_ok();
    if !fast {
        // DLT transforms
        let spec = ConvSpec::new(16, 32, 32, 32, 3, 3, 1, 1, 1);
        let t = Tensor::random(16, 32, 32, &mut rng);
        let ltu = Ltu::tensor3d_to_toeplitz(&spec);
        let mut dst = vec![0.0f32; 16 * 9 * 32 * 32];
        b.bench("dlt/tensor3d_to_toeplitz/16x32x32_3x3", || {
            ltu.gather(&t.data, &mut dst);
            dst[0]
        });
        let ltu_w = Ltu::tensor3d_to_wino(16, 32, 32, 2, 3, 1);
        let mut dst_w = vec![0.0f32; ltu_w.len()];
        b.bench("dlt/tensor3d_to_wino/16x32x32", || {
            ltu_w.gather(&t.data, &mut dst_w);
            dst_w[0]
        });

        // whole-layer simulation per algorithm: one-shot (weights
        // lowered per call) vs prepared (lowered once)
        let lspec = ConvSpec::new(8, 8, 16, 16, 3, 3, 1, 1, 1);
        let input = Tensor::random(8, 16, 16, &mut rng);
        let wts = Weights::random(8, 8, 3, 3, &mut rng);
        for algo in [Algo::Im2col, Algo::Kn2row, Algo::Winograd { m: 2, r: 3 }] {
            b.bench(&format!("layer_sim/8x16x16_3x3/{}", algo.name()), || {
                simulate_layer(&input, &wts, &lspec, algo, Dataflow::NS, 16, 16)
            });
            let pw = prepare_layer(&wts, &lspec, algo);
            b.bench(&format!("layer_sim_prepared/8x16x16_3x3/{}", algo.name()), || {
                simulate_layer_prepared(&input, &pw, Dataflow::NS, 16, 16)
            });
        }

        // pooling pipeline
        let pspec = PoolSpec { kind: PoolKind::Max, c: 64, h1: 28, h2: 28, k: 3, s: 2, p: 1 };
        let pin = Tensor::random(64, 28, 28, &mut rng);
        b.bench("pooling/hpu_vpu/64x28x28", || pooling::simulate(&pin, &pspec, 16));
    }

    // ---- end-to-end batch serving: before vs after this perf pass ----
    let cnn = zoo::mini_inception();
    let mut weights = BTreeMap::new();
    let mut prepared = BTreeMap::new();
    for node in &cnn.nodes {
        let Op::Conv(spec) = &node.op else { continue };
        let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, &mut rng);
        prepared.insert(node.name.clone(), PreparedWeights::new(&w, spec, algo_for(spec)));
        weights.insert(node.name.clone(), w);
    }
    let n_req = 8;
    let inputs: Vec<Tensor> =
        (0..n_req).map(|_| Tensor::random(4, 16, 16, &mut rng)).collect();

    let base = b
        .bench(&format!("infer_batch/mini-inception/{n_req}req/baseline_seq_rederive"), || {
            inputs
                .iter()
                .map(|inp| infer_rederive(&cnn, &weights, inp))
                .collect::<Vec<_>>()
        })
        .clone();
    let fast = b
        .bench(&format!("infer_batch/mini-inception/{n_req}req/prepared_parallel"), || {
            parallel_map(&inputs, |_, inp| infer_prepared(&cnn, &prepared, inp))
        })
        .clone();
    let speedup = base.mean.as_secs_f64() / fast.mean.as_secs_f64();
    println!(
        "infer_batch speedup (prepared weights + parallel serving vs pre-change \
         sequential re-derivation): {speedup:.2}x  (target >= 2x)"
    );
    // enforced gate: `DYNAMAP_BENCH_ASSERT=1 cargo bench` fails the run
    // on a regression below the PR's acceptance threshold (plain runs
    // only report, so noisy shared runners don't flake)
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok() {
        assert!(
            speedup >= 2.0,
            "infer_batch speedup regressed below the 2x acceptance gate: {speedup:.2}x"
        );
    }
}
