//! `cargo bench` driver regenerating EVERY paper table and figure:
//! Fig. 1, Figs. 9–12, Tables 3–4, the <2 s DSE-runtime claim and the
//! ablation suite. Timing of the DSE stages themselves is measured with
//! the mini-criterion harness.

use dynamap::api::Compiler;
use dynamap::bench::figures;
use dynamap::bench::harness::Bencher;
use dynamap::graph::zoo;

fn main() {
    // full figure regeneration is many complete DSEs over googlenet and
    // inception-v4 — real runs want it, the CI bench-smoke job
    // (DYNAMAP_BENCH_FAST=1) only needs the benches to execute
    if std::env::var("DYNAMAP_BENCH_FAST").is_ok() {
        println!("DYNAMAP_BENCH_FAST set: skipping paper figure regeneration (smoke mode)\n");
    } else {
        println!("=== regenerating paper tables & figures ===\n");
        for (tables, stem) in [
            (figures::fig01::run(), "fig01_algo_loads"),
            (figures::util_figs::run("inception-v4"), "fig09_util_inception_v4"),
            (figures::util_figs::run("googlenet"), "fig10_util_googlenet"),
            (figures::module_figs::run("inception-v4"), "fig11_modules_inception_v4"),
            (figures::module_figs::run("googlenet"), "fig12_modules_googlenet"),
            (figures::table3::run(), "table3_sota"),
            (figures::table4::run(), "table4_improvement"),
            (figures::dse_runtime::run(), "dse_runtime"),
            (figures::ablations::run(), "ablations"),
        ] {
            figures::emit(&tables, Some("reports"), stem);
        }
    }

    println!("\n=== DSE stage timings ===");
    let mut b = Bencher::new();
    for model in ["googlenet", "inception-v4"] {
        let cnn = zoo::by_name(model).unwrap();
        let compiler = Compiler::new();
        b.bench(&format!("algo1/{model}"), || compiler.identify(&cnn).unwrap());
        let arch = compiler.identify(&cnn).unwrap();
        b.bench(&format!("cost_graph/{model}"), || {
            compiler.build_graph(&cnn, arch.p1, arch.p2)
        });
        let g = compiler.build_graph(&cnn, arch.p1, arch.p2);
        b.bench(&format!("pbqp_solve/{model}"), || g.solve(&cnn));
        b.bench(&format!("full_dse/{model}"), || compiler.compile(&cnn).unwrap());
    }
}
