//! `cargo bench` target for the quantized int8 serving path: the
//! headline f32-vs-int8 GEMM comparison on the ROADMAP's fixed
//! `128×96×128` shape (prints `int8 gemm speedup: N.NNx`;
//! `DYNAMAP_BENCH_ASSERT=1` turns the ≥1.5× threshold into a hard
//! failure), plus prepared-layer conv comparisons and an end-to-end
//! mixed-precision `infer_batch` on mini-inception.
//!
//! The int8 measurements deliberately include the per-call activation
//! quantization pass (dynamic per-tensor scale) — that is what the
//! serving path pays — while weights are pre-quantized once, exactly
//! like the f32 side's pre-packed `Wᵀ` panels.

use std::collections::BTreeMap;

use dynamap::algos::tensor::{Mat, Tensor, Weights};
use dynamap::bench::harness::Bencher;
use dynamap::cost::conv::Algo;
use dynamap::graph::layer::Op;
use dynamap::graph::zoo;
use dynamap::kernels::{self, PackedWt, PackedWtI8, PreparedWeights, QuantMat};
use dynamap::quant::Precision;
use dynamap::util::parallel::parallel_map;
use dynamap::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let mut rng = Rng::new(99);

    // ---- the gated comparison: f32 vs int8 GEMM on 128×96×128 ----
    let x = Mat::from_fn(128, 96, |_, _| rng.f32_range(-1.0, 1.0));
    let w = Mat::from_fn(96, 128, |_, _| rng.f32_range(-0.5, 0.5));
    let wt = PackedWt::pack(&w);
    let wq = PackedWtI8::quantize(&w);
    let f32_m = b.bench("gemm/128x96x128/f32_packed", || kernels::gemm(&x, &wt)).clone();
    let i8_m = b
        .bench("gemm/128x96x128/int8_quantize+qgemm", || {
            kernels::qgemm(&QuantMat::quantize(&x), &wq)
        })
        .clone();
    let speedup = f32_m.mean.as_secs_f64() / i8_m.mean.as_secs_f64();
    println!("int8 gemm speedup: {speedup:.2}x  (target >= 1.5x)");

    // ---- prepared-layer conv: f32 vs int8, im2col and kn2row ----
    let spec = dynamap::graph::layer::ConvSpec::new(16, 32, 16, 16, 3, 3, 1, 1, 1);
    let input = Tensor::random(16, 16, 16, &mut rng);
    let wts = Weights::random(32, 16, 3, 3, &mut rng);
    for algo in [Algo::Im2col, Algo::Kn2row] {
        let f = PreparedWeights::new(&wts, &spec, algo);
        let q = PreparedWeights::with_precision(&wts, &spec, algo, Precision::Int8, None);
        assert_eq!(q.precision(), Precision::Int8);
        b.bench(&format!("conv/16x16x16_3x3/{}/f32", algo.name()), || f.conv2d(&input));
        b.bench(&format!("conv/16x16x16_3x3/{}/int8", algo.name()), || q.conv2d(&input));
    }

    // ---- end-to-end: mini-inception batch, f32 map vs mixed map ----
    // quantize every im2col/kn2row layer, keep winograd (3×3) at f32 —
    // the shape of plan the precision-aware DSE produces
    let cnn = zoo::mini_inception();
    let mut prep_f32 = BTreeMap::new();
    let mut prep_mixed = BTreeMap::new();
    for node in &cnn.nodes {
        let Op::Conv(spec) = &node.op else { continue };
        let w = Weights::random(spec.c_out, spec.c_in, spec.k1, spec.k2, &mut rng);
        let algo = match spec.k1 {
            3 => Algo::Winograd { m: 2, r: 3 },
            _ => Algo::Im2col,
        };
        prep_f32.insert(node.name.clone(), PreparedWeights::new(&w, spec, algo));
        prep_mixed.insert(
            node.name.clone(),
            PreparedWeights::with_precision(&w, spec, algo, Precision::Int8, None),
        );
    }
    let n_req = 8;
    let inputs: Vec<Tensor> =
        (0..n_req).map(|_| Tensor::random(4, 16, 16, &mut rng)).collect();
    let infer = |prep: &BTreeMap<String, PreparedWeights>, input: &Tensor| -> Tensor {
        let mut values: BTreeMap<usize, Tensor> = BTreeMap::new();
        let mut out = None;
        for id in cnn.topo_order() {
            let node = cnn.node(id);
            let preds = cnn.predecessors(id);
            let t = match &node.op {
                Op::Input { .. } => input.clone(),
                Op::Conv(_) => prep[&node.name].conv2d(&values[&preds[0]]),
                Op::Pool(p) => dynamap::overlay::pooling::reference(&values[&preds[0]], p),
                Op::Concat { c_out, h1, h2 } => {
                    let mut data = Vec::with_capacity(c_out * h1 * h2);
                    for &p in &preds {
                        data.extend_from_slice(&values[&p].data);
                    }
                    Tensor { c: *c_out, h: *h1, w: *h2, data }
                }
                Op::Output => {
                    out = Some(values[&preds[0]].clone());
                    continue;
                }
                _ => unreachable!("mini-inception has no add/fc layers"),
            };
            values.insert(id, t);
        }
        out.expect("graph has an output")
    };
    let e2e_f32 = b
        .bench(&format!("infer_batch/mini-inception/{n_req}req/f32"), || {
            parallel_map(&inputs, |_, inp| infer(&prep_f32, inp))
        })
        .clone();
    let e2e_mixed = b
        .bench(&format!("infer_batch/mini-inception/{n_req}req/mixed_int8"), || {
            parallel_map(&inputs, |_, inp| infer(&prep_mixed, inp))
        })
        .clone();
    println!(
        "mixed-precision infer_batch speedup (informational): {:.2}x",
        e2e_f32.mean.as_secs_f64() / e2e_mixed.mean.as_secs_f64()
    );

    // enforced gate: `DYNAMAP_BENCH_ASSERT=1 cargo bench` fails the run
    // when the int8 kernel loses its packing advantage (plain runs only
    // report, so noisy shared runners don't flake)
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok() {
        assert!(
            speedup >= 1.5,
            "int8 gemm speedup regressed below the 1.5x acceptance gate: {speedup:.2}x"
        );
    }
}
