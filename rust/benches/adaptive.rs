//! `cargo bench` target for the online adaptation loop (ROADMAP
//! §Performance, PR 4 methodology — fixed seed 99, release profile,
//! `DYNAMAP_BENCH_FAST` unset for real numbers).
//!
//! Start from a deliberately mis-calibrated device: kn2row and
//! Winograd priced ~10000× too cheap, so the DSE maps mini-inception's
//! conv layers away from im2col even where im2col is actually fastest
//! on this host. Serve profiled traffic, then run the
//! profile → calibrate → remap loop to convergence (no further swaps)
//! and measure the same 8-request `infer_batch` workload before and
//! after. The run prints `adaptive remap speedup: N.NNx` so ROADMAP.md
//! has a number to append; `DYNAMAP_BENCH_ASSERT=1` turns the ≥1.2×
//! threshold into a hard failure on hosts with ≥4 cores (plain runs
//! only report — the gap between algorithm families is a property of
//! the host's cache hierarchy, and single-core CI boxes are too noisy
//! to gate on).

use std::collections::BTreeMap;

use dynamap::api::{Compiler, Device};
use dynamap::bench::harness::Bencher;
use dynamap::cost::DeviceCalibration;
use dynamap::runtime::TensorBuf;
use dynamap::serve::{BatchConfig, ModelRegistry, RegistryConfig};
use dynamap::tune::{calibrate, remap, RemapConfig};
use dynamap::util::parallel::worker_count;
use dynamap::util::rng::Rng;

fn algo_histogram(map: &BTreeMap<String, String>) -> BTreeMap<String, usize> {
    let mut h = BTreeMap::new();
    for algo in map.values() {
        *h.entry(algo.clone()).or_insert(0) += 1;
    }
    h
}

fn main() {
    let mut b = Bencher::new();
    let root = std::env::temp_dir()
        .join(format!("dynamap_adaptive_bench_{}", std::process::id()));
    std::fs::remove_dir_all(&root).ok();

    // the deliberately mis-calibrated device
    let skew = DeviceCalibration::default()
        .with("kn2row", 1e-4, 0.0)
        .with("winograd", 1e-4, 0.0);
    let registry = ModelRegistry::new(RegistryConfig {
        artifacts_root: root.join("zoo"),
        plan_cache: None,
        capacity: 2,
        synthesize_missing: true,
        seed: 99,
        compiler: Compiler::new().device(Device::small_edge()).calibration(skew),
        batch: BatchConfig::default(),
        max_inflight: 0,
        profile: true,
        slos: Default::default(),
    });
    let host = registry.host("mini-inception").expect("host mini-inception");
    println!(
        "  mis-calibrated plan: {:?}",
        algo_histogram(host.state().algo_map())
    );

    // fixed 8-request workload, seed 99 (ROADMAP methodology)
    let (c, h1, h2) = host.input_dims();
    let mut rng = Rng::new(99);
    let inputs: Vec<TensorBuf> = (0..8)
        .map(|_| {
            TensorBuf::new(
                vec![c, h1, h2],
                (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect(),
            )
        })
        .collect();
    let warm = |n: usize| {
        for _ in 0..n {
            host.state().infer_batch(&inputs).expect("profiled warm-up batch");
        }
    };
    warm(16); // populate the profiler before the first calibration

    let before = b
        .bench("adaptive/mini-inception/8req/mis-calibrated", || {
            host.state().infer_batch(&inputs).expect("pre-remap batch").0.len()
        })
        .clone();

    // profile → calibrate → remap to convergence (hysteresis stops it)
    let mut swaps = 0usize;
    for pass in 0..4 {
        let state = host.state();
        let (p1, p2) = host.plan_shape().expect("registry hosts carry a plan shape");
        let profile = host.profile().expect("profiling is on");
        let cal = calibrate(
            state.cnn(),
            &registry.config().compiler,
            p1,
            p2,
            &profile.snapshot(),
        )
        .expect("calibration over profiled traffic");
        let outcome = remap(&registry, "mini-inception", &cal, &RemapConfig::default())
            .expect("remap");
        println!("  pass {pass}: {}", outcome.summary());
        if !outcome.swapped {
            break;
        }
        swaps += 1;
        warm(8); // refresh observations under the new plan
    }
    println!(
        "  calibrated plan after {swaps} swap(s): {:?}",
        algo_histogram(host.state().algo_map())
    );

    let after = b
        .bench("adaptive/mini-inception/8req/calibrated", || {
            host.state().infer_batch(&inputs).expect("post-remap batch").0.len()
        })
        .clone();

    let speedup = before.mean.as_secs_f64() / after.mean.as_secs_f64();
    println!(
        "adaptive remap speedup (calibrated plan vs deliberately mis-calibrated \
         device): {speedup:.2}x"
    );
    // enforced gate: needs real parallel headroom and at least one swap
    if std::env::var("DYNAMAP_BENCH_ASSERT").is_ok() && worker_count(8) >= 4 {
        assert!(swaps >= 1, "the adaptation loop never swapped a plan");
        assert!(
            speedup >= 1.2,
            "adaptive remap speedup regressed below the 1.2x gate: {speedup:.2}x"
        );
    }
    registry.shutdown();
    std::fs::remove_dir_all(&root).ok();
}
