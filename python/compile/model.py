"""L2 — the MiniInception model: the paper's per-layer dynamic
algorithm mapping embodied as a JAX forward graph whose every conv
layer dispatches to one of the three L1 Pallas kernel families.

Layer names and shapes MUST stay in sync with the Rust model zoo
(``rust/src/graph/zoo/mini.rs``) — the AOT artifact manifest is keyed
by these names and the Rust coordinator chains the per-layer
executables according to its PBQP mapping.
"""

import numpy as np
import jax.numpy as jnp

from .kernels import im2col, kn2row, ref, winograd

MINI_INPUT = (4, 16, 16)  # (C, H, W)

# name, c_in, c_out, (h1, h2), (k1, k2), stride, (p1, p2)
MINI_LAYERS = [
    ("stem", 4, 8, (16, 16), (3, 3), 1, (1, 1)),
    ("inc/b1_1x1", 8, 8, (16, 16), (1, 1), 1, (0, 0)),
    ("inc/b2_reduce", 8, 4, (16, 16), (1, 1), 1, (0, 0)),
    ("inc/b2_3x3", 4, 8, (16, 16), (3, 3), 1, (1, 1)),
    ("inc/b3_reduce", 8, 4, (16, 16), (1, 1), 1, (0, 0)),
    ("inc/b3_5x5", 4, 8, (16, 16), (5, 5), 1, (2, 2)),
    ("head", 24, 16, (8, 8), (1, 1), 1, (0, 0)),
]

ALGOS = ("im2col", "kn2row", "winograd")


def layer_meta(name):
    for row in MINI_LAYERS:
        if row[0] == name:
            return row
    raise KeyError(name)


def algos_for(name):
    """Algorithm families AOT-compiled for a layer: the Pallas Winograd
    path implements F(2,3) for 3×3 stride-1 kernels (the Rust cost model
    additionally decomposes 5×5 — that path is exercised in Rust tests;
    artifacts stick to the kernels implemented at L1)."""
    _, _, _, _, (k1, k2), s, _ = (None, *layer_meta(name)[1:])
    if k1 == 3 and k2 == 3 and s == 1:
        return ("im2col", "kn2row", "winograd")
    return ("im2col", "kn2row")


def conv_layer(x, w, algo, stride, pad):
    """Dispatch one conv layer to the chosen L1 kernel family."""
    if algo == "im2col":
        return im2col.conv2d(x, w, stride, pad)
    if algo == "kn2row":
        return kn2row.conv2d(x, w, stride, pad)
    if algo == "winograd":
        return winograd.conv2d(x, w, stride, pad)
    raise ValueError(f"unknown algo {algo}")


def init_weights(seed=1234):
    """Deterministic He-style weights for every layer (numpy, so the
    bytes written to the artifact dir are reproducible)."""
    rng = np.random.default_rng(seed)
    weights = {}
    for name, c_in, c_out, _hw, (k1, k2), _s, _p in MINI_LAYERS:
        fan_in = c_in * k1 * k2
        weights[name] = (
            rng.standard_normal((c_out, c_in, k1, k2)) / np.sqrt(fan_in)
        ).astype(np.float32)
    return weights


def forward(x, weights, algo_map=None, relu=True):
    """Full MiniInception forward pass.

    ``algo_map`` maps layer name → algorithm ("im2col" default). The
    graph mirrors ``zoo::mini_inception``: stem → 3 branches → concat →
    2×2 maxpool → head.
    """
    algo_map = algo_map or {}

    def conv(name, inp):
        _, _, _, _, k, s, p = layer_meta(name)
        out = conv_layer(inp, jnp.asarray(weights[name]), algo_map.get(name, "im2col"), s, p)
        return jnp.maximum(out, 0.0) if relu else out

    stem = conv("stem", x)
    b1 = conv("inc/b1_1x1", stem)
    b2 = conv("inc/b2_3x3", conv("inc/b2_reduce", stem))
    b3 = conv("inc/b3_5x5", conv("inc/b3_reduce", stem))
    cat = jnp.concatenate([b1, b2, b3], axis=0)  # (24, 16, 16)
    pool = ref.maxpool2d(cat, 2, 2, 0)  # (24, 8, 8)
    return conv("head", pool)


def forward_ref(x, weights, relu=True):
    """Oracle forward pass through lax.conv only (no Pallas)."""

    def conv(name, inp):
        _, _, _, _, _k, s, p = layer_meta(name)
        out = ref.conv2d(inp, jnp.asarray(weights[name]), s, p)
        return jnp.maximum(out, 0.0) if relu else out

    stem = conv("stem", x)
    b1 = conv("inc/b1_1x1", stem)
    b2 = conv("inc/b2_3x3", conv("inc/b2_reduce", stem))
    b3 = conv("inc/b3_5x5", conv("inc/b3_reduce", stem))
    cat = jnp.concatenate([b1, b2, b3], axis=0)
    pool = ref.maxpool2d(cat, 2, 2, 0)
    return conv("head", pool)
