"""AOT pipeline: lower every (layer, algorithm) pair of MiniInception to
HLO *text* and emit the artifact manifest the Rust runtime consumes.

HLO text — NOT ``lowered.compiler_ir("hlo")`` protos or
``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs under ``--out`` (default ``../artifacts``):

* ``conv__<name>__<algo>.hlo.txt`` — one executable per pair; the
  computation is ``relu(conv(x, w))`` with fixed shapes, lowered with
  ``return_tuple=True`` (unwrap with ``to_tuple1`` on the Rust side).
* ``weights__<name>.bin`` — float32 little-endian weight payloads.
* ``golden_input.bin`` / ``golden_output.bin`` — a seeded input and the
  oracle (lax.conv) forward output for end-to-end validation.
* ``manifest.json`` — layer meta data, artifact paths, golden shapes.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path).

    ``print_large_constants=True`` is ESSENTIAL: the default printer
    elides big dense literals as ``constant({...})`` and the 0.5.1 text
    parser silently materializes those as zeros — every kernel that
    bakes a constant table (Winograd's B/G/A matrices, closed-over
    weights) would produce wrong numbers at runtime.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "elided constants survived"
    return text


def safe(name: str) -> str:
    return name.replace("/", "_")


def lower_layer(name: str, algo: str) -> str:
    """Lower relu(conv(x, w)) for one (layer, algo) pair to HLO text."""
    _, c_in, c_out, (h1, h2), k, s, p = model.layer_meta(name)

    def fn(x, w):
        out = model.conv_layer(x, w, algo, s, p)
        return (jnp.maximum(out, 0.0),)

    x_spec = jax.ShapeDtypeStruct((c_in, h1, h2), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((c_out, c_in, k[0], k[1]), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec, w_spec)
    return to_hlo_text(lowered)


def lower_fused(algo_map) -> str:
    """Whole-network fused artifact (one executable, XLA fuses across
    layers) — the L2-optimization comparison point for the engine's
    per-layer chaining."""
    weights = model.init_weights()

    def fn(x):
        return (model.forward(x, weights, algo_map),)

    x_spec = jax.ShapeDtypeStruct(model.MINI_INPUT, jnp.float32)
    lowered = jax.jit(fn).lower(x_spec)
    return to_hlo_text(lowered)


def golden_pair(weights, seed=42):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(model.MINI_INPUT).astype(np.float32)
    y = np.asarray(model.forward_ref(jnp.asarray(x), weights))
    return x, y


def golden_layers(weights, seed=42):
    """Per-layer (input, expected-output) pairs along the oracle forward
    pass — lets the Rust runtime validate every (layer, algo) artifact
    in isolation."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(model.MINI_INPUT).astype(np.float32))

    def conv(name, inp):
        _, _, _, _, _k, s, p = model.layer_meta(name)
        out = ref.conv2d(inp, jnp.asarray(weights[name]), s, p)
        return jnp.maximum(out, 0.0)

    ios = {}
    stem = conv("stem", x)
    ios["stem"] = (x, stem)
    b1 = conv("inc/b1_1x1", stem)
    ios["inc/b1_1x1"] = (stem, b1)
    b2r = conv("inc/b2_reduce", stem)
    ios["inc/b2_reduce"] = (stem, b2r)
    b2 = conv("inc/b2_3x3", b2r)
    ios["inc/b2_3x3"] = (b2r, b2)
    b3r = conv("inc/b3_reduce", stem)
    ios["inc/b3_reduce"] = (stem, b3r)
    b3 = conv("inc/b3_5x5", b3r)
    ios["inc/b3_5x5"] = (b3r, b3)
    cat = jnp.concatenate([b1, b2, b3], axis=0)
    pool = ref.maxpool2d(cat, 2, 2, 0)
    head = conv("head", pool)
    ios["head"] = (pool, head)
    return {k: (np.asarray(i), np.asarray(o)) for k, (i, o) in ios.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-fused", action="store_true", help="skip the fused whole-net artifact")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    weights = model.init_weights()
    layers = []
    for name, c_in, c_out, (h1, h2), (k1, k2), s, (p1, p2) in model.MINI_LAYERS:
        o1, o2 = ref.out_dims(h1, h2, k1, k2, s, (p1, p2))
        algo_files = {}
        for algo in model.algos_for(name):
            fname = f"conv__{safe(name)}__{algo}.hlo.txt"
            text = lower_layer(name, algo)
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            algo_files[algo] = fname
            print(f"  lowered {name} [{algo}] -> {fname} ({len(text)} chars)")
        wfile = f"weights__{safe(name)}.bin"
        weights[name].tofile(os.path.join(args.out, wfile))
        layers.append(
            {
                "name": name,
                "c_in": c_in,
                "c_out": c_out,
                "h1": h1,
                "h2": h2,
                "k1": k1,
                "k2": k2,
                "s": s,
                "p1": p1,
                "p2": p2,
                "o1": o1,
                "o2": o2,
                "algos": algo_files,
                "weights": wfile,
                "weight_count": int(weights[name].size),
            }
        )

    x, y = golden_pair(weights)
    x.tofile(os.path.join(args.out, "golden_input.bin"))
    y.tofile(os.path.join(args.out, "golden_output.bin"))

    for name, (gi, go) in golden_layers(weights).items():
        gi.tofile(os.path.join(args.out, f"golden_in__{safe(name)}.bin"))
        go.tofile(os.path.join(args.out, f"golden_out__{safe(name)}.bin"))

    manifest = {
        "model": "mini-inception",
        "input": {"c": model.MINI_INPUT[0], "h1": model.MINI_INPUT[1], "h2": model.MINI_INPUT[2]},
        "golden_input": "golden_input.bin",
        "golden_output": "golden_output.bin",
        "golden_output_shape": list(y.shape),
        "layers": layers,
    }

    if not args.skip_fused:
        fused = lower_fused({})
        with open(os.path.join(args.out, "fused__im2col.hlo.txt"), "w") as f:
            f.write(fused)
        manifest["fused"] = "fused__im2col.hlo.txt"
        print(f"  lowered fused network ({len(fused)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(layers)} layers to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
