"""kn2row convolution (paper §2.1.2) on the Pallas GEMM kernel.

Phase 1 — "unit-CONV GEMM": ``K1·K2`` calls of
``W_tap (C_out × C_in) · X (C_in × H1H2)`` (Eq. 3), no input
duplication. Phase 2 — "Pad-and-Accumulate": each intermediate patch is
shifted by its kernel-tap offset, zero-padded on the non-overlap and
Hadamard-added (Eq. 4); stride handled by the strided gather.
"""

import jax.numpy as jnp

from . import gemm_pallas, ref


def conv2d(x, w, stride=1, pad=(0, 0)):
    """kn2row convolution; same contract as :func:`ref.conv2d`."""
    c_out, c_in, k1, k2 = w.shape
    _, h1, h2 = x.shape
    o1, o2 = ref.out_dims(h1, h2, k1, k2, stride, pad)
    xm = x.reshape(c_in, h1 * h2)  # 3D-tensor layout — no duplication
    acc = jnp.zeros((c_out, o1, o2), x.dtype)
    for ky in range(k1):
        for kx in range(k2):
            patch = gemm_pallas.matmul(w[:, :, ky, kx], xm)  # (C_out, H1H2)
            patch = patch.reshape(c_out, h1, h2)
            # pad-and-accumulate: output (oy, ox) takes patch value at
            # (oy·s + ky − p1, ox·s + kx − p2) — realized as a padded
            # strided slice (out-of-range ⇒ the zero padding)
            pp = jnp.pad(patch, ((0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
            shifted = pp[:, ky : ky + o1 * stride : stride, kx : kx + o2 * stride : stride]
            acc = acc + shifted
    return acc
