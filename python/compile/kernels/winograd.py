"""Winograd F(2×2, 3×3) convolution (paper §2.1.3) on the Pallas GEMM.

Equation-6 form: input tiles and kernels are transformed
(``V = BᵀdB``, ``U = GgGᵀ``), the Hadamard products become
``(m+r−1)² = 16`` independent ``(tiles × C_in) · (C_in × C_out)``
GEMMs — each dispatched to the Pallas tiled kernel — and the inverse
transform ``Y = AᵀMA`` restores the spatial tiles. 3×3 kernels,
stride 1, any symmetric padding; output dims need not be tile-aligned.
"""

import jax.numpy as jnp

from . import gemm_pallas, ref

BT = jnp.array(
    [
        [1.0, 0.0, -1.0, 0.0],
        [0.0, 1.0, 1.0, 0.0],
        [0.0, -1.0, 1.0, 0.0],
        [0.0, 1.0, 0.0, -1.0],
    ]
)
G = jnp.array(
    [
        [1.0, 0.0, 0.0],
        [0.5, 0.5, 0.5],
        [0.5, -0.5, 0.5],
        [0.0, 0.0, 1.0],
    ]
)
AT = jnp.array(
    [
        [1.0, 1.0, 1.0, 0.0],
        [0.0, 1.0, -1.0, -1.0],
    ]
)

M = 2
R = 3
A = M + R - 1  # 4


def conv2d(x, w, stride=1, pad=(1, 1)):
    """Winograd convolution; same contract as :func:`ref.conv2d`."""
    assert stride == 1, "winograd kernel is stride-1"
    c_out, c_in, k1, k2 = w.shape
    assert k1 == 3 and k2 == 3, "the AOT'd Pallas path implements F(2,3)"
    _, h1, h2 = x.shape
    o1, o2 = ref.out_dims(h1, h2, 3, 3, 1, pad)
    t1 = -(-o1 // M)
    t2 = -(-o2 // M)

    # gather overlapping 4×4 input tiles: (C_in, T1, T2, 4, 4)
    need_h = (t1 - 1) * M + A
    need_w = (t2 - 1) * M + A
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (pad[0], max(0, need_h - h1 - pad[0])),
            (pad[1], max(0, need_w - h2 - pad[1])),
        ),
    )
    tiles = jnp.stack(
        [
            jnp.stack(
                [
                    xp[:, ty * M : ty * M + A, tx * M : tx * M + A]
                    for tx in range(t2)
                ],
                axis=1,
            )
            for ty in range(t1)
        ],
        axis=1,
    )  # (C_in, T1, T2, 4, 4)

    # V = Bᵀ d B for every tile: (C_in, T1, T2, 4, 4)
    v = jnp.einsum("ab,ctubd,ed->ctuae", BT, tiles, BT)
    # U = G g Gᵀ: (C_out, C_in, 4, 4)
    u = jnp.einsum("ab,oibd,ed->oiae", G, w, G)

    # 16 independent GEMMs (Eq. 6): for each point (ξ, ν):
    #   M[:, :] = V_point (T1T2 × C_in) @ U_point (C_in × C_out)
    nt = t1 * t2
    m_pts = []
    for py in range(A):
        for px in range(A):
            v_p = v[:, :, :, py, px].reshape(c_in, nt).T  # (tiles, C_in)
            u_p = u[:, :, py, px].T  # (C_in, C_out)
            m_pts.append(gemm_pallas.matmul(v_p, u_p))  # (tiles, C_out)
    m_all = jnp.stack(m_pts).reshape(A, A, nt, c_out)

    # inverse transform Y = Aᵀ M A: (tiles, C_out, 2, 2)
    y = jnp.einsum("ab,bdtc,ed->tcae", AT, m_all, AT)
    y = y.reshape(t1, t2, c_out, M, M)
    # concatenate tiles → (C_out, T1·2, T2·2), crop to (O1, O2)
    y = jnp.transpose(y, (2, 0, 3, 1, 4)).reshape(c_out, t1 * M, t2 * M)
    return y[:, :o1, :o2]
