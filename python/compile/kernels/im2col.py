"""im2col convolution (paper §2.1.1) on the Pallas GEMM kernel.

The Toeplitz matrix is materialized with strided slices (the jnp
analogue of the DLT module's Table-1 row-1 walk) and fed to the tiled
GEMM — Eq. 2: ``z = W (C_out × K1K2C_in) · X (K1K2C_in × O1O2)``.
"""

import jax.numpy as jnp

from . import gemm_pallas, ref


def toeplitz(x, k1, k2, stride=1, pad=(0, 0)):
    """(C_in·K1·K2, O1·O2) Toeplitz matrix, row = (ci·K1+ky)·K2+kx."""
    c_in, h1, h2 = x.shape
    o1, o2 = ref.out_dims(h1, h2, k1, k2, stride, pad)
    xp = jnp.pad(x, ((0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    rows = []
    for ci in range(c_in):
        for ky in range(k1):
            for kx in range(k2):
                window = xp[ci, ky : ky + o1 * stride : stride, kx : kx + o2 * stride : stride]
                rows.append(window.reshape(-1))
    return jnp.stack(rows)


def conv2d(x, w, stride=1, pad=(0, 0)):
    """im2col convolution; same contract as :func:`ref.conv2d`."""
    c_out, c_in, k1, k2 = w.shape
    _, h1, h2 = x.shape
    o1, o2 = ref.out_dims(h1, h2, k1, k2, stride, pad)
    xm = toeplitz(x, k1, k2, stride, pad)  # (C_in·K1K2, O1O2)
    wm = w.reshape(c_out, c_in * k1 * k2)  # matching row order
    z = gemm_pallas.matmul(wm, xm)  # (C_out, O1O2)
    return z.reshape(c_out, o1, o2)
