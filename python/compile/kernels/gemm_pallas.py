"""L1 — tiled GEMM Pallas kernel.

The BlockSpec tiling mirrors the overlay's ``P_SA1 × P_SA2`` systolic
blocking: the output is computed in ``(bm × bn)`` tiles while the
contraction dimension streams through in ``bk`` chunks — the same
HBM↔VMEM schedule the FPGA overlay expresses with its Input/Kernel
buffer banks (DESIGN.md §Hardware-Adaptation). On a real TPU the
``(bm, bn)`` tile feeds the MXU systolic array exactly like the paper's
PE grid; here we run ``interpret=True`` so the kernel lowers to plain
HLO the CPU PJRT client can execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; grid axis 2 streams the k dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul(x, y, bm=32, bk=32, bn=32):
    """``x (m × k) @ y (k × n)`` via the Pallas tiled kernel.

    Tile sizes default to MXU-friendly 32; shapes need not divide the
    tiles (Pallas masks the fringe).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul dims {x.shape} @ {y.shape}"
    bm = min(bm, m)
    bk = min(bk, k)
    bn = min(bn, n)
    # pad every dim to a tile multiple: interpret-mode Pallas fills
    # out-of-bounds block reads with NaN (deliberately, to surface OOB
    # bugs), so fringe blocks must not exist. This is also what the
    # overlay does in hardware — zero-padding the last tile (the PE
    # utilization loss Eq. 14 measures).
    mp = pl.cdiv(m, bm) * bm
    kp = pl.cdiv(k, bk) * bk
    np_ = pl.cdiv(n, bn) * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        y = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y)
    return out[:m, :n]
