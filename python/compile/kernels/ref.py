"""Reference convolution oracle.

Two independent references:

* :func:`conv2d` — XLA's ``lax.conv_general_dilated``, the production
  oracle every kernel is validated against.
* :func:`conv2d_loops` — a hand-written jnp sliding-window sum used to
  sanity-check the oracle itself on tiny shapes (the two references are
  independent code paths).

Tensors are CHW (no batch dim — the paper targets single-image,
no-batch inference); weights are ``(C_out, C_in, K1, K2)``.
"""

import jax.numpy as jnp
from jax import lax


def conv2d(x, w, stride=1, pad=(0, 0)):
    """Spatial convolution (Eq. 1 of the paper).

    x: (C_in, H1, H2), w: (C_out, C_in, K1, K2) -> (C_out, O1, O2).
    ``pad`` is symmetric (p1, p2).
    """
    x4 = x[None]  # NCHW
    out = lax.conv_general_dilated(
        x4,
        w,
        window_strides=(stride, stride),
        padding=((pad[0], pad[0]), (pad[1], pad[1])),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def conv2d_loops(x, w, stride=1, pad=(0, 0)):
    """Independent sliding-window reference (small shapes only)."""
    c_in, h1, h2 = x.shape
    c_out, _, k1, k2 = w.shape
    o1 = (h1 + 2 * pad[0] - k1) // stride + 1
    o2 = (h2 + 2 * pad[1] - k2) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad[0], pad[0]), (pad[1], pad[1])))
    out = jnp.zeros((c_out, o1, o2), x.dtype)
    for ky in range(k1):
        for kx in range(k2):
            window = xp[:, ky : ky + o1 * stride : stride, kx : kx + o2 * stride : stride]
            # (C_out, C_in) x (C_in, O1, O2) summed over C_in
            out = out + jnp.einsum("oc,chw->ohw", w[:, :, ky, kx], window)
    return out


def out_dims(h1, h2, k1, k2, stride, pad):
    """(O1, O2) for the given layer meta data."""
    return (
        (h1 + 2 * pad[0] - k1) // stride + 1,
        (h2 + 2 * pad[1] - k2) // stride + 1,
    )


def maxpool2d(x, k, stride, pad=0):
    """MaxPool reference used by the model graph (C, H, W)."""
    neg = jnp.finfo(x.dtype).min
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=neg)
    c, h, w = xp.shape
    o1 = (h - k) // stride + 1
    o2 = (w - k) // stride + 1
    out = jnp.full((c, o1, o2), neg, x.dtype)
    for ky in range(k):
        for kx in range(k):
            out = jnp.maximum(out, xp[:, ky : ky + o1 * stride : stride, kx : kx + o2 * stride : stride])
    return out
