"""AOT pipeline tests: HLO-text lowering, manifest integrity, golden
reproducibility."""

import json
import os
import subprocess
import sys

import numpy as np

from compile import aot, model


def test_lower_layer_produces_hlo_text():
    text = aot.lower_layer("inc/b2_reduce", "im2col")
    assert "HloModule" in text
    assert "f32[4,16,16]" in text  # input shape baked in


def test_lower_all_pairs_smoke():
    for name, *_ in model.MINI_LAYERS:
        for algo in model.algos_for(name):
            text = aot.lower_layer(name, algo)
            assert "HloModule" in text, f"{name}/{algo}"
            # return_tuple=True → tuple-rooted computation
            assert "ROOT" in text


def test_golden_deterministic():
    w = model.init_weights()
    x1, y1 = aot.golden_pair(w)
    x2, y2 = aot.golden_pair(w)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == model.MINI_INPUT
    assert y1.shape == (16, 8, 8)


def test_manifest_matches_build(tmp_path=None):
    # the repo-level artifacts dir is produced by `make artifacts`; if
    # present, validate its manifest against the model table.
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        import pytest

        pytest.skip("artifacts not built")
    man = json.load(open(man_path))
    assert man["model"] == "mini-inception"
    assert len(man["layers"]) == len(model.MINI_LAYERS)
    for layer in man["layers"]:
        meta = model.layer_meta(layer["name"])
        assert layer["c_in"] == meta[1]
        assert layer["c_out"] == meta[2]
        for algo, fname in layer["algos"].items():
            path = os.path.join(art, fname)
            assert os.path.exists(path), f"missing artifact {fname}"
            assert "HloModule" in open(path).read(200)
        wpath = os.path.join(art, layer["weights"])
        w = np.fromfile(wpath, dtype=np.float32)
        assert w.size == layer["weight_count"]


def test_safe_name():
    assert aot.safe("inc/b2_3x3") == "inc_b2_3x3"
