"""L1 conv kernels (im2col / kn2row / winograd) vs the lax.conv oracle
— hypothesis sweeps layer shapes; plus the oracle self-check."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import im2col, kn2row, ref, winograd


def rand_case(seed, c_in, c_out, h, k1, k2):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, (c_in, h, h), jnp.float32)
    w = jax.random.normal(kw, (c_out, c_in, k1, k2), jnp.float32)
    return x, w


channels = st.integers(min_value=1, max_value=5)
heights = st.integers(min_value=7, max_value=14)
kernels = st.sampled_from([(1, 1), (3, 3), (5, 5), (1, 7), (7, 1), (1, 3), (3, 1)])
strides = st.integers(min_value=1, max_value=2)
same_pad = st.booleans()


@settings(max_examples=25, deadline=None)
@given(ci=channels, co=channels, h=heights, k=kernels, s=strides, sp=same_pad)
def test_im2col_matches_ref(ci, co, h, k, s, sp):
    k1, k2 = k
    pad = (k1 // 2, k2 // 2) if sp else (0, 0)
    x, w = rand_case(ci * 100 + co * 10 + h, ci, co, max(h, k1, k2), k1, k2)
    got = im2col.conv2d(x, w, s, pad)
    want = ref.conv2d(x, w, s, pad)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=25, deadline=None)
@given(ci=channels, co=channels, h=heights, k=kernels, s=strides, sp=same_pad)
def test_kn2row_matches_ref(ci, co, h, k, s, sp):
    k1, k2 = k
    pad = (k1 // 2, k2 // 2) if sp else (0, 0)
    x, w = rand_case(ci * 99 + co * 9 + h, ci, co, max(h, k1, k2), k1, k2)
    got = kn2row.conv2d(x, w, s, pad)
    want = ref.conv2d(x, w, s, pad)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(ci=channels, co=channels, h=heights, sp=same_pad)
def test_winograd_matches_ref(ci, co, h, sp):
    pad = (1, 1) if sp else (0, 0)
    x, w = rand_case(ci * 77 + co * 7 + h, ci, co, h, 3, 3)
    got = winograd.conv2d(x, w, 1, pad)
    want = ref.conv2d(x, w, 1, pad)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(ci=channels, co=channels, h=st.integers(min_value=5, max_value=9))
def test_oracle_self_check(ci, co, h):
    # lax.conv vs the independent loop reference
    x, w = rand_case(h * 31 + ci, ci, co, h, 3, 3)
    a = ref.conv2d(x, w, 1, (1, 1))
    b = ref.conv2d_loops(x, w, 1, (1, 1))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_toeplitz_shape_and_duplication():
    x = jnp.arange(2 * 5 * 5, dtype=jnp.float32).reshape(2, 5, 5)
    t = im2col.toeplitz(x, 3, 3, 1, (1, 1))
    assert t.shape == (2 * 9, 25)
    # center row of the toeplitz equals the flat input (identity tap)
    np.testing.assert_allclose(t[4], x[0].reshape(-1))


def test_maxpool_reference():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4)
    out = ref.maxpool2d(x, 2, 2)
    np.testing.assert_allclose(out[0], jnp.array([[5.0, 7.0], [13.0, 15.0]]))
