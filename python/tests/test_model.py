"""L2 model tests: shapes, algorithm-map equivalence (the functional
core of dynamic algorithm mapping: ANY per-layer algorithm assignment
must produce the same network output), and oracle agreement."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model


def _input(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(model.MINI_INPUT).astype(np.float32))


def test_forward_shape():
    w = model.init_weights()
    y = model.forward(_input(), w)
    assert y.shape == (16, 8, 8)


def test_forward_matches_oracle():
    w = model.init_weights()
    x = _input(1)
    got = model.forward(x, w)
    want = model.forward_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


algo_choice = st.sampled_from(["im2col", "kn2row", "winograd"])


@settings(max_examples=8, deadline=None)
@given(
    stem=algo_choice,
    b2=algo_choice,
    b1=st.sampled_from(["im2col", "kn2row"]),
    b3=st.sampled_from(["im2col", "kn2row"]),
)
def test_any_algorithm_mapping_is_equivalent(stem, b2, b1, b3):
    """The paper's premise: algorithm choice changes cost, not values."""
    w = model.init_weights()
    x = _input(2)
    amap = {
        "stem": stem,
        "inc/b2_3x3": b2,
        "inc/b1_1x1": b1,
        "inc/b3_5x5": b3,
    }
    got = model.forward(x, w, amap)
    want = model.forward_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_layer_meta_consistency():
    # channel flow: concat inputs sum to head c_in
    cat = sum(
        model.layer_meta(n)[2]
        for n in ("inc/b1_1x1", "inc/b2_3x3", "inc/b3_5x5")
    )
    assert cat == model.layer_meta("head")[1] == 24


def test_algos_for_rules():
    assert model.algos_for("stem") == ("im2col", "kn2row", "winograd")
    assert model.algos_for("inc/b3_5x5") == ("im2col", "kn2row")
    assert model.algos_for("head") == ("im2col", "kn2row")


def test_weights_deterministic():
    a = model.init_weights()
    b = model.init_weights()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_unknown_algo_raises():
    w = model.init_weights()
    with pytest.raises(ValueError):
        model.conv_layer(_input(), jnp.asarray(w["stem"]), "fft", 1, (1, 1))


def test_all_single_algo_maps_agree():
    """im2col-only vs kn2row-only vs mixed on every conv layer."""
    w = model.init_weights()
    x = _input(3)
    outs = []
    for algo in ("im2col", "kn2row"):
        amap = {name: algo for name, *_ in model.MINI_LAYERS}
        outs.append(model.forward(x, w, amap))
    for a, b in itertools.combinations(outs, 2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
