"""L1 Pallas GEMM kernel vs jnp reference — hypothesis sweeps shapes,
dtypes and tile sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_pallas

dims = st.integers(min_value=1, max_value=65)
tile = st.sampled_from([4, 8, 16, 32])


@settings(max_examples=30, deadline=None)
@given(m=dims, k=dims, n=dims, bm=tile, bk=tile, bn=tile)
def test_matmul_matches_jnp(m, k, n, bm, bk, bn):
    kx, ky = jax.random.split(jax.random.PRNGKey(m * 1000 + k * 10 + n))
    x = jax.random.normal(kx, (m, k), jnp.float32)
    y = jax.random.normal(ky, (k, n), jnp.float32)
    got = gemm_pallas.matmul(x, y, bm=bm, bk=bk, bn=bn)
    want = x @ y
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=dims, k=dims, n=dims)
def test_matmul_f64_via_f32_cast(m, k, n):
    # the kernel is dtype-generic; exercise another dtype path (bf16)
    kx, ky = jax.random.split(jax.random.PRNGKey(m + k + n))
    x = jax.random.normal(kx, (m, k), jnp.float32).astype(jnp.bfloat16)
    y = jax.random.normal(ky, (k, n), jnp.float32).astype(jnp.bfloat16)
    got = gemm_pallas.matmul(x, y)
    want = (x @ y).astype(jnp.float32)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=0.1, atol=0.25
    )


def test_identity():
    x = jnp.eye(8, dtype=jnp.float32)
    y = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    np.testing.assert_allclose(gemm_pallas.matmul(x, y), y)


def test_non_divisible_fringe():
    # 33×17 @ 17×9 with 8-tiles: every dimension has a fringe block
    x = jnp.arange(33 * 17, dtype=jnp.float32).reshape(33, 17) / 100.0
    y = jnp.arange(17 * 9, dtype=jnp.float32).reshape(17, 9) / 100.0
    got = gemm_pallas.matmul(x, y, bm=8, bk=8, bn=8)
    np.testing.assert_allclose(got, x @ y, rtol=1e-4, atol=1e-4)


def test_dim_mismatch_raises():
    x = jnp.zeros((4, 5))
    y = jnp.zeros((6, 4))
    with pytest.raises(AssertionError):
        gemm_pallas.matmul(x, y)
