//! Artifact checking tool: execute any HLO-text artifact with raw f32
//! input files and compare against an expected output — the debugging
//! harness for the AOT ⇄ PJRT interchange.
//!
//! ```bash
//! cargo run --release --example artifact_check -- \
//!     --hlo artifacts/conv__stem__winograd.hlo.txt \
//!     --inputs artifacts/golden_in__stem.bin:4x16x16,artifacts/weights__stem.bin:8x4x3x3 \
//!     --expect artifacts/golden_out__stem.bin
//! ```

use dynamap::runtime::{PjrtRuntime, TensorBuf};
use dynamap::util::cli::Args;

fn read_f32(path: &str) -> Vec<f32> {
    let bytes = std::fs::read(path).unwrap_or_else(|e| panic!("read {path}: {e}"));
    bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

fn parse_shape(s: &str) -> Vec<usize> {
    s.split('x').map(|d| d.parse().expect("bad shape")).collect()
}

fn main() {
    let args = Args::parse_env(&[]);
    let hlo = args.get("hlo").expect("--hlo required");
    let inputs_arg = args.get("inputs").expect("--inputs required (file:shape,file:shape)");
    let expect_path = args.get("expect");

    let mut inputs = Vec::new();
    for part in inputs_arg.split(',') {
        let (file, shape) = part.split_once(':').expect("input format file:AxBxC");
        let shape = parse_shape(shape);
        let data = read_f32(file);
        assert_eq!(
            data.len(),
            shape.iter().product::<usize>(),
            "{file}: {} elements but shape {shape:?}",
            data.len()
        );
        inputs.push(TensorBuf::new(shape, data));
    }

    let mut rt = PjrtRuntime::cpu().expect("pjrt client");
    let refs: Vec<&TensorBuf> = inputs.iter().collect();
    // output shape = expected file length (flat) or explicit --out-shape
    let expect = expect_path.map(read_f32);
    let out_len = expect
        .as_ref()
        .map(|e| e.len())
        .or_else(|| args.get("out-len").and_then(|v| v.parse().ok()))
        .expect("--expect or --out-len required");
    let out = rt
        .execute(std::path::Path::new(hlo), &refs, vec![out_len])
        .expect("execute");
    match expect {
        Some(e) => {
            let max_err = out
                .data
                .iter()
                .zip(&e)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            // locate the first big mismatch for debugging
            let first = out
                .data
                .iter()
                .zip(&e)
                .position(|(a, b)| (a - b).abs() > 1e-3);
            println!("max |Δ| = {max_err:.3e} first mismatch at {first:?}");
            if let Some(i) = first {
                let lo = i.saturating_sub(2);
                println!("  got[{lo}..]    = {:?}", &out.data[lo..(lo + 6).min(out.data.len())]);
                println!("  expect[{lo}..] = {:?}", &e[lo..(lo + 6).min(e.len())]);
                std::process::exit(1);
            }
            println!("OK");
        }
        None => println!("output ({} elems): {:?}…", out.data.len(), &out.data[..out.data.len().min(8)]),
    }
}
