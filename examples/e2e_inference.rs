//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose: the Rust coordinator loads the
//! AOT-compiled Pallas/JAX artifacts (`make artifacts`), picks the
//! per-layer algorithm with the DSE flow, runs real batched inference
//! requests through PJRT, validates numerics against the Python oracle
//! golden, and reports latency/throughput for every mapping policy.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use dynamap::coordinator::{EnginePolicy, InferenceEngine};
use dynamap::cost::graph_build::Policy;
use dynamap::runtime::TensorBuf;
use dynamap::util::rng::Rng;
use dynamap::util::table::Table;

fn main() {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests = 64;

    let mut table = Table::new(
        "end-to-end inference — mini-inception through PJRT (64 requests)",
        &["policy", "mapping", "golden max|Δ|", "mean µs", "p95 µs", "req/s"],
    );

    for (label, policy) in [
        ("OPT (DYNAMAP)", EnginePolicy::Optimal),
        ("bl3 im2col", EnginePolicy::Baseline(Policy::Im2colOnly)),
        ("bl4 kn2row", EnginePolicy::Baseline(Policy::Kn2rowApplied)),
        ("bl5 winograd", EnginePolicy::Baseline(Policy::WinoApplied)),
    ] {
        let mut engine = match InferenceEngine::new(&dir, policy) {
            Ok(e) => e,
            Err(e) => {
                eprintln!("({label}) engine init failed: {e}\nrun `make artifacts` first");
                std::process::exit(1);
            }
        };
        // 1. numeric validation against the Python-side oracle
        let max_err = engine.validate_golden().expect("golden validation");
        assert!(max_err < 1e-3, "{label}: golden mismatch {max_err}");

        // 2. serve a batch of synthetic requests
        let (c, h1, h2) = engine.manifest.input;
        let mut rng = Rng::new(2024);
        let mut stats = dynamap::coordinator::LatencyStats::new();
        // warm-up
        let warm = random_input(&mut rng, c, h1, h2);
        engine.infer(&warm).unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..n_requests {
            let input = random_input(&mut rng, c, h1, h2);
            let (_out, m) = engine.infer(&input).expect("inference");
            stats.push(m.total_us);
        }
        let wall = t0.elapsed().as_secs_f64();

        let hist: std::collections::BTreeMap<&str, usize> =
            engine.algo_map.values().fold(Default::default(), |mut h, a| {
                *h.entry(a.as_str()).or_insert(0) += 1;
                h
            });
        table.row(vec![
            label.into(),
            format!("{hist:?}"),
            format!("{max_err:.1e}"),
            format!("{:.0}", stats.mean()),
            format!("{:.0}", stats.percentile(95.0)),
            format!("{:.0}", n_requests as f64 / wall),
        ]);
    }
    println!("{}", table.render());
    println!("all policies validated against the Python oracle ✓");
}

fn random_input(rng: &mut Rng, c: usize, h1: usize, h2: usize) -> TensorBuf {
    let data: Vec<f32> = (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    TensorBuf::new(vec![c, h1, h2], data)
}
