//! END-TO-END VALIDATION DRIVER (EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose through the staged API: a `Session`
//! loads the AOT-compiled Pallas/JAX artifacts (`make artifacts`),
//! resolves the model from the manifest, compiles (and caches) the DSE
//! plan, runs real batched inference requests through PJRT, validates
//! numerics against the Python oracle golden, and reports
//! latency/throughput for every mapping policy.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use dynamap::api::{Policy, Session};
use dynamap::runtime::TensorBuf;
use dynamap::util::rng::Rng;
use dynamap::util::table::Table;

fn main() {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_requests = 64;
    // plans compiled once are reused across the baseline sweep (and
    // across runs of this example)
    let plan_cache = std::env::temp_dir().join("dynamap_e2e_plans");

    let mut table = Table::new(
        "end-to-end inference — batched requests through a PJRT Session (64 requests)",
        &["policy", "mapping", "golden max|Δ|", "mean µs", "p95 µs", "req/s", "plan"],
    );

    for (label, policy) in [
        ("OPT (DYNAMAP)", None),
        ("bl3 im2col", Some(Policy::Im2colOnly)),
        ("bl4 kn2row", Some(Policy::Kn2rowApplied)),
        ("bl5 winograd", Some(Policy::WinoApplied)),
    ] {
        let mut builder = Session::builder(dir.as_str()).plan_cache(&plan_cache);
        if let Some(p) = policy {
            builder = builder.policy(p);
        }
        let mut session = match builder.build() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("({label}) session init failed: {e}\nrun `make artifacts` first");
                std::process::exit(1);
            }
        };
        // 1. numeric validation against the Python-side oracle
        let max_err = session.validate_golden().expect("golden validation");
        assert!(max_err < 1e-3, "{label}: golden mismatch {max_err}");

        // 2. serve a batch of synthetic requests through infer_batch
        let (c, h1, h2) = session.manifest().input;
        let mut rng = Rng::new(2024);
        // warm-up
        let warm = random_input(&mut rng, c, h1, h2);
        session.infer(&warm).unwrap();
        let batch: Vec<TensorBuf> =
            (0..n_requests).map(|_| random_input(&mut rng, c, h1, h2)).collect();
        let t0 = std::time::Instant::now();
        let (outputs, metrics) = session.infer_batch(&batch).expect("batched inference");
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(outputs.len(), n_requests);
        assert_eq!(metrics.stats.count(), n_requests);

        let hist: std::collections::BTreeMap<&str, usize> =
            session.algo_map().values().fold(Default::default(), |mut h, a| {
                *h.entry(a.as_str()).or_insert(0) += 1;
                h
            });
        table.row(vec![
            label.into(),
            format!("{hist:?}"),
            format!("{max_err:.1e}"),
            format!("{:.0}", metrics.stats.mean()),
            format!("{:.0}", metrics.stats.percentile(95.0)),
            format!("{:.0}", n_requests as f64 / wall),
            if session.plan_from_cache() { "cached".into() } else { "compiled".into() },
        ]);
    }
    println!("{}", table.render());
    println!("all policies validated against the Python oracle ✓");
}

fn random_input(rng: &mut Rng, c: usize, h1: usize, h2: usize) -> TensorBuf {
    let data: Vec<f32> = (0..c * h1 * h2).map(|_| rng.f32_range(-1.0, 1.0)).collect();
    TensorBuf::new(vec![c, h1, h2], data)
}
