//! Quickstart: run the full DYNAMAP DSE flow on GoogLeNet and print the
//! chosen architecture + per-layer algorithm mapping.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dynamap::cost::graph_build::Policy;
use dynamap::dse::{Dse, DseConfig};
use dynamap::graph::zoo;
use dynamap::util::table::Table;

fn main() {
    // 1. pick a network from the zoo (or load your own — see
    //    examples/custom_cnn.rs)
    let cnn = zoo::googlenet();
    println!("{}\n", cnn.summary());

    // 2. configure the target device (the paper's Alveo U200 setup)
    let dse = Dse::new(DseConfig::alveo_u200());

    // 3. run the two-step DSE: Algorithm 1 + optimal PBQP mapping
    let t0 = std::time::Instant::now();
    let plan = dse.run(&cnn).expect("DSE failed");
    println!(
        "DSE finished in {:.2?}: P_SA = {}×{}, end-to-end latency {:.3} ms, {:.0} GOP/s",
        t0.elapsed(),
        plan.p1,
        plan.p2,
        plan.total_latency_ms,
        plan.throughput_gops
    );
    println!("algorithm histogram: {:?}\n", plan.algo_histogram());

    // 4. compare against the single-algorithm baselines of §6.1.2
    let mut t = Table::new("OPT vs baselines", &["mapping", "latency ms", "×"]);
    t.row(vec!["OPT".into(), format!("{:.3}", plan.total_latency_ms), "1.00".into()]);
    for (label, p) in [
        ("bl3 im2col-only", Policy::Im2colOnly),
        ("bl4 kn2row-applied", Policy::Kn2rowApplied),
        ("bl5 wino-applied", Policy::WinoApplied),
    ] {
        let bl = dse.run_policy(&cnn, p).unwrap();
        t.row(vec![
            label.into(),
            format!("{:.3}", bl.total_latency_ms),
            format!("{:.2}", bl.total_latency_ms / plan.total_latency_ms),
        ]);
    }
    println!("{}", t.render());

    // 5. the first few per-layer decisions
    let mut t = Table::new(
        "per-layer mapping (first 12 layers)",
        &["layer", "algo", "dataflow", "μ"],
    );
    for l in plan.mapping.layers.iter().take(12) {
        t.row(vec![
            l.name.clone(),
            l.cost.algo.name(),
            l.cost.dataflow.name().into(),
            format!("{:.3}", l.cost.utilization),
        ]);
    }
    println!("{}", t.render());
}
