//! Quickstart: the staged `Compiler → PlanArtifact → Session` pipeline.
//!
//! 1. *Compile* (offline, expensive): run the two-step DSE once on
//!    GoogLeNet and get a `PlanArtifact`.
//! 2. *Persist* the artifact and load it back — the DSE result is a
//!    durable value keyed by `(model, device, config)`, not something to
//!    recompute per process.
//! 3. *Serve* (online, cheap): a `Session` would load this plan and run
//!    inference against AOT artifacts — see `examples/e2e_inference.rs`
//!    for that half (it needs `make artifacts`), or `dynamap serve` /
//!    `dynamap loadgen` for the multi-model engine, which needs no
//!    artifacts at all. The same flow as this example is doc-tested on
//!    `Session::builder`.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dynamap::api::{Compiler, PlanArtifact, Policy};
use dynamap::graph::zoo;
use dynamap::util::table::Table;

fn main() {
    // 1. pick a network from the zoo (or load your own — see
    //    examples/custom_cnn.rs)
    let cnn = zoo::googlenet();
    println!("{}\n", cnn.summary());

    // 2. configure the compiler (defaults = the paper's Alveo U200
    //    setup) and run the two-step DSE: Algorithm 1 + PBQP mapping
    let compiler = Compiler::new().wino(2, 3);
    let t0 = std::time::Instant::now();
    let artifact = compiler.compile(&cnn).expect("DSE failed");
    let plan = &artifact.plan;
    println!(
        "compile finished in {:.2?}: P_SA = {}×{}, end-to-end latency {:.3} ms, {:.0} GOP/s",
        t0.elapsed(),
        plan.p1,
        plan.p2,
        plan.total_latency_ms,
        plan.throughput_gops
    );
    println!("algorithm histogram: {:?}\n", plan.algo_histogram());

    // 3. the artifact is versioned and fully round-trippable: save it,
    //    load it back, and serve from it later without re-running DSE
    let path = std::env::temp_dir().join("dynamap_quickstart_googlenet.json");
    artifact.save(&path).expect("save plan artifact");
    let reloaded = PlanArtifact::load(&path).expect("load plan artifact");
    assert_eq!(reloaded.plan.mapping.assignment, plan.mapping.assignment);
    println!(
        "plan artifact round-tripped through {} (schema v{})\n",
        path.display(),
        reloaded.version
    );

    // 4. compare against the single-algorithm baselines of §6.1.2
    let mut t = Table::new("OPT vs baselines", &["mapping", "latency ms", "×"]);
    t.row(vec!["OPT".into(), format!("{:.3}", plan.total_latency_ms), "1.00".into()]);
    for (label, p) in [
        ("bl3 im2col-only", Policy::Im2colOnly),
        ("bl4 kn2row-applied", Policy::Kn2rowApplied),
        ("bl5 wino-applied", Policy::WinoApplied),
    ] {
        let bl = compiler.clone().policy(p).compile(&cnn).unwrap().into_plan();
        t.row(vec![
            label.into(),
            format!("{:.3}", bl.total_latency_ms),
            format!("{:.2}", bl.total_latency_ms / plan.total_latency_ms),
        ]);
    }
    println!("{}", t.render());

    // 5. the first few per-layer decisions
    let mut t = Table::new(
        "per-layer mapping (first 12 layers)",
        &["layer", "algo", "dataflow", "μ"],
    );
    for l in plan.mapping.layers.iter().take(12) {
        t.row(vec![
            l.name.clone(),
            l.cost.algo.name(),
            l.cost.dataflow.name().into(),
            format!("{:.3}", l.cost.utilization),
        ]);
    }
    println!("{}", t.render());
    println!(
        "next: `make artifacts && cargo run --release --example e2e_inference` \
         to serve this pipeline through a PJRT Session, or \
         `dynamap loadgen --models mini,googlenet --compare` for the \
         multi-model batching engine (no artifacts needed)"
    );
}
