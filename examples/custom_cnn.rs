//! Bring-your-own-CNN: define a network programmatically (or load a
//! JSON description), then run the DSE flow against a *different*
//! device budget — showing DYNAMAP adapting `(P_SA1, P_SA2)` and the
//! algorithm mapping to both the network and the hardware.
//!
//! ```bash
//! cargo run --release --example custom_cnn            # built-in demo net
//! cargo run --release --example custom_cnn -- my.json # your own JSON
//! ```

use dynamap::api::Compiler;
use dynamap::cost::Device;
use dynamap::graph::layer::{Op, PoolKind};
use dynamap::graph::{config, Cnn, CnnBuilder};
use dynamap::util::table::Table;

/// A MobileNet-flavoured edge CNN: narrow channels, several stride-2
/// stages, a couple of 5×5 layers — deliberately different from the
/// zoo networks.
fn demo_net() -> Cnn {
    let mut b = CnnBuilder::new("edge-demo");
    let inp = b.add("input", Op::Input { c: 3, h1: 96, h2: 96 }, &[]);
    let c1 = b.conv("conv1", inp, 16, (3, 3), 2, (1, 1));
    let c2 = b.conv_same("conv2", c1, 32, (3, 3));
    let p1 = b.pool("pool1", c2, PoolKind::Max, 2, 2, 0);
    let c3 = b.conv_same("conv3", p1, 48, (5, 5));
    let c4 = b.conv_same("conv4", c3, 48, (1, 1));
    let branch_a = b.conv_same("branch_a", c4, 32, (3, 3));
    let branch_b = b.conv_same("branch_b", c4, 32, (1, 5));
    let cat = b.concat("concat", &[branch_a, branch_b]);
    let p2 = b.pool("pool2", cat, PoolKind::Max, 2, 2, 0);
    let _head = b.conv_same("head", p2, 96, (1, 1));
    b.finish(3, 96)
}

fn main() {
    let cnn = match std::env::args().nth(1) {
        Some(path) => config::load(&path).expect("load JSON CNN"),
        None => demo_net(),
    };
    println!("{}\n", cnn.summary());

    // save the demo net as JSON so users have a starting template
    if std::env::args().nth(1).is_none() {
        config::save(&cnn, "/tmp/edge_demo_cnn.json").ok();
        println!("(wrote the demo network JSON to /tmp/edge_demo_cnn.json)\n");
    }

    let mut t = Table::new(
        "DSE across device budgets",
        &["device", "DSP cap", "P_SA", "latency ms", "GOP/s", "algo histogram"],
    );
    for device in [Device::alveo_u200(), Device::small_edge()] {
        let compiler = Compiler::new().device(device.clone());
        let plan = compiler.compile(&cnn).expect("DSE").into_plan();
        t.row(vec![
            device.name.clone(),
            device.dsp_cap.to_string(),
            format!("{}×{}", plan.p1, plan.p2),
            format!("{:.3}", plan.total_latency_ms),
            format!("{:.0}", plan.throughput_gops),
            format!("{:?}", plan.algo_histogram()),
        ]);
    }
    println!("{}", t.render());
}
